"""Content-addressed artifact store + AOT/persistent-cache tests.

Covers the zero-cold-start invariant end to end: content identity
(``repro.store.content``), the template-free typed-path checkpoint format
it serializes through, the store's atomicity/corruption/GC behavior, the
digest-keyed sweep dedup, the engine/trainer step-cache stats + AOT
``warmup`` paths, Session store plumbing — and, in a subprocess pair, the
cross-process guarantee: a second process re-running a previously-seen
sweep against a warm store performs **0 XLA compiles and 0 feature
extractions** and reproduces every metric bit for bit.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.api import ArtifactStore, Session, Trace
from repro.ckpt import load_array_tree, save_array_tree
from repro.core import FeatureConfig, TaoConfig
from repro.core.features import extract_features
from repro.core.model import init_tao
from repro.core.transfer import train_tao_impl, warmup_train_step
from repro.engine import EngineConfig, StreamingEngine, cache_stats, clear_step_cache
from repro.engine.scheduler import SweepJob, TraceSweeper
from repro.store import array_digest, config_token, content_key, tree_digest
from repro.train.trainer import cache_stats as train_cache_stats
from repro.train.trainer import clear_train_step_cache
from repro.uarch import UARCH_A, get_benchmark, run_functional

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = TaoConfig(
    window=9, d_model=16, n_heads=2, n_layers=1, d_ff=32, d_cat=8,
    features=FeatureConfig(n_buckets=64, n_queue=4, n_mem=8),
)


@pytest.fixture(scope="module")
def trace():
    return run_functional(get_benchmark("dee"), 1200)


@pytest.fixture(scope="module")
def params():
    return init_tao(jax.random.PRNGKey(0), CFG)


# ---------------------------------------------------------------------------
# Content identity
# ---------------------------------------------------------------------------


def test_array_digest_content_not_identity(trace):
    other = trace.copy()  # distinct object, equal content
    assert other is not trace
    assert array_digest(other) == array_digest(trace)
    mutated = trace.copy()
    mutated["opcode"][0] += 1
    assert array_digest(mutated) != array_digest(trace)


def test_array_digest_dtype_and_shape_sensitive():
    a = np.zeros(8, np.int32)
    assert array_digest(a) != array_digest(a.astype(np.float32))
    assert array_digest(a) != array_digest(a.reshape(2, 4))
    # non-contiguous views digest by content, not memory layout
    b = np.arange(16, dtype=np.int32)
    assert array_digest(b[::2]) == array_digest(np.ascontiguousarray(b[::2]))


def test_tree_digest_structure_sensitive():
    x = np.arange(4.0)
    assert tree_digest({"a": x, "b": x}) != tree_digest({"a": x, "c": x})
    assert tree_digest([x, x]) != tree_digest([x])
    assert tree_digest({"a": {"b": x}}) != tree_digest({"a": {"c": x}})


def test_config_token_and_content_key_stability():
    t1 = config_token(CFG)
    t2 = config_token(
        TaoConfig(window=9, d_model=16, n_heads=2, n_layers=1, d_ff=32,
                  d_cat=8, features=FeatureConfig(n_buckets=64, n_queue=4, n_mem=8))
    )
    assert t1 == t2
    assert content_key("params", t1) == content_key("params", t2)
    # kind namespaces the key
    assert content_key("params", t1) != content_key("features", t1)
    with pytest.raises(TypeError):
        config_token(object())


def test_trace_and_featureset_digest(trace):
    tr = Trace(name="t", functional=trace, program=get_benchmark("dee"))
    assert tr.digest == array_digest(trace)
    fs = extract_features(trace, CFG.features, with_labels=False)
    fs2 = extract_features(trace.copy(), CFG.features, with_labels=False)
    assert fs.digest == fs2.digest
    assert fs.digest == fs.digest  # cached property path


# ---------------------------------------------------------------------------
# Typed-path checkpoint format (template-free restore)
# ---------------------------------------------------------------------------


def test_array_tree_roundtrip_nested_and_list(tmp_path):
    tree = {
        "embed": {"w": np.arange(12.0, dtype=np.float32).reshape(3, 4)},
        "blocks": [
            {"k": np.ones((2, 2), np.float32)},
            {"k": np.zeros((2, 2), np.float32)},
        ],
        "scalar": np.float32(3.5),
    }
    save_array_tree(tree, str(tmp_path / "e"), extra={"note": "hi"})
    got, extra = load_array_tree(str(tmp_path / "e"))
    assert extra == {"note": "hi"}
    assert isinstance(got["blocks"], list) and len(got["blocks"]) == 2
    np.testing.assert_array_equal(got["embed"]["w"], tree["embed"]["w"])
    np.testing.assert_array_equal(got["blocks"][1]["k"], tree["blocks"][1]["k"])
    assert got["scalar"] == np.float32(3.5)


def test_array_tree_roundtrip_structured_and_bf16(tmp_path, trace):
    import jax.numpy as jnp

    tree = {"trace": trace, "bf": np.arange(6, dtype=np.dtype(jnp.bfloat16))}
    save_array_tree(tree, str(tmp_path / "e"))
    got, _ = load_array_tree(str(tmp_path / "e"))
    np.testing.assert_array_equal(got["trace"], trace)
    assert got["bf"].dtype == np.dtype(jnp.bfloat16)
    np.testing.assert_array_equal(
        got["bf"].astype(np.float32), tree["bf"].astype(np.float32)
    )


def test_array_tree_truncation_detected(tmp_path):
    save_array_tree({"w": np.arange(100.0)}, str(tmp_path / "e"))
    # truncate the payload: load must fail loudly, not return garbage
    for name in os.listdir(tmp_path / "e"):
        if name.endswith(".bin"):
            p = tmp_path / "e" / name
            with open(p, "r+b") as f:
                f.truncate(10)
    with pytest.raises(ValueError, match="truncated"):
        load_array_tree(str(tmp_path / "e"))


# ---------------------------------------------------------------------------
# ArtifactStore: atomicity, corruption-as-miss, GC
# ---------------------------------------------------------------------------


def test_store_roundtrip_and_counters(tmp_path):
    st = ArtifactStore(str(tmp_path / "s"))
    key = content_key("features", "abc")
    assert st.get("features", key) is None          # miss
    assert st.put("features", key, {"x": np.arange(3.0)}, {"n": 3})
    assert not st.put("features", key, {"x": np.arange(3.0)})  # immutable
    assert st.has("features", key)
    tree, extra = st.get("features", key)
    np.testing.assert_array_equal(tree["x"], np.arange(3.0))
    assert extra == {"n": 3}
    s = st.stats()
    assert s["entries"] == 1 and s["hits"] == 1 and s["misses"] == 1
    assert s["puts"] == 1 and s["bytes"] > 0


def test_store_corruption_quarantined(tmp_path):
    st = ArtifactStore(str(tmp_path / "s"))
    key = content_key("params", "k")
    st.put("params", key, {"w": np.arange(50.0)})
    edir = st._entry_dir("params", key)
    for name in os.listdir(edir):
        if name.endswith(".bin"):
            with open(os.path.join(edir, name), "r+b") as f:
                f.truncate(4)
    assert st.get("params", key) is None            # corrupt -> miss
    assert st.counters["corrupt_dropped"] == 1
    assert not st.has("params", key)                # quarantined (deleted)
    # recompute-and-reput works
    assert st.put("params", key, {"w": np.arange(50.0)})
    assert st.get("params", key) is not None


def test_store_gc_budget_and_age(tmp_path):
    st = ArtifactStore(str(tmp_path / "s"))
    for i in range(4):
        st.put("features", content_key("features", i), {"x": np.arange(100.0)})
    assert st.stats()["entries"] == 4
    out = st.gc(max_bytes=st.stats()["bytes"] // 2)
    assert out["evicted"] >= 1
    assert st.stats()["entries"] < 4
    st.gc(max_age_s=0.0)                            # everything is "old"
    assert st.stats()["entries"] == 0
    # stale staging dirs are swept, fresh ones are left alone
    os.makedirs(os.path.join(st.root, "tmp", "torn-123-1"))
    os.utime(os.path.join(st.root, "tmp", "torn-123-1"), (0, 0))
    st.gc()
    assert not os.path.exists(os.path.join(st.root, "tmp", "torn-123-1"))


def test_store_self_gc_with_max_bytes(tmp_path):
    st = ArtifactStore(str(tmp_path / "s"), max_bytes=1)
    st.put("features", content_key("features", 1), {"x": np.arange(100.0)})
    st.put("features", content_key("features", 2), {"x": np.arange(100.0)})
    assert st.stats()["entries"] <= 1               # each put GCs to budget


# ---------------------------------------------------------------------------
# Pinning: readers block GC (the serving regression)
# ---------------------------------------------------------------------------


def test_store_pin_blocks_gc_same_host(tmp_path):
    st = ArtifactStore(str(tmp_path / "s"))
    k1, k2 = content_key("features", 1), content_key("features", 2)
    st.put("features", k1, {"x": np.arange(10.0)})
    st.put("features", k2, {"x": np.arange(10.0) + 1})
    other = ArtifactStore(str(tmp_path / "s"))      # GC from "elsewhere"
    with st.pin("features", k1) as pinned:
        assert pinned
        other.gc(max_age_s=0.0)
        assert st.has("features", k1)               # pinned entry survives
        assert not st.has("features", k2)           # unpinned is collected
        assert other.counters["gc_pin_skips"] == 1
        # byte-budget pass also skips the pinned entry
        other.gc(max_bytes=0)
        assert st.has("features", k1)
    other.gc(max_age_s=0.0)                         # pin released
    assert not st.has("features", k1)
    # explicit delete is an operator decision: it ignores pins
    st.put("features", k1, {"x": np.arange(10.0)})
    with st.pin("features", k1):
        assert st.delete("features", k1)
    assert not st.has("features", k1)


def test_store_pin_missing_entry_and_stale_pid(tmp_path):
    st = ArtifactStore(str(tmp_path / "s"))
    # pinning a never-published entry reports pinned=False (caller treats
    # it as an ordinary miss and recomputes)
    with st.pin("features", content_key("features", "never")) as pinned:
        assert not pinned
    # a stale marker from a dead pid must not block GC forever
    k = content_key("features", "x")
    st.put("features", k, {"x": np.arange(3.0)})
    edir = st._entry_dir("features", k)
    open(os.path.join(edir, ".pin-999999999-1"), "x").close()
    st.gc(max_age_s=0.0)
    assert not st.has("features", k)
    assert st.counters["gc_pin_skips"] == 0


_PIN_CHILD = r"""
import sys
from repro.api import ArtifactStore
st = ArtifactStore(sys.argv[1])
with st.pin(sys.argv[2], sys.argv[3]) as pinned:
    print("PINNED" if pinned else "MISSING", flush=True)
    sys.stdin.readline()                  # hold the pin until released
print("DONE", flush=True)
"""


def test_store_pin_cross_process(tmp_path):
    """A serving process streaming an entry pins it; GC in this process
    must skip it until the reader exits (ISSUE satellite regression)."""
    root = str(tmp_path / "s")
    st = ArtifactStore(root)
    k = content_key("serve_model", "served")
    st.put("serve_model", k, {"w": np.arange(20.0)})
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    p = subprocess.Popen(
        [sys.executable, "-c", _PIN_CHILD, root, "serve_model", k],
        stdin=subprocess.PIPE, stdout=subprocess.PIPE, text=True,
        env=env, cwd=ROOT,
    )
    try:
        assert p.stdout.readline().strip() == "PINNED"
        st.gc(max_age_s=0.0)
        assert st.has("serve_model", k)             # reader keeps it alive
        assert st.counters["gc_pin_skips"] == 1
    finally:
        p.stdin.write("\n")
        p.stdin.flush()
        assert p.wait(timeout=120) == 0
    st.gc(max_age_s=0.0)
    assert not st.has("serve_model", k)


# ---------------------------------------------------------------------------
# Step-cache stats + AOT warmup (engine and trainer)
# ---------------------------------------------------------------------------


def test_engine_cache_stats_and_clear(trace, params):
    clear_step_cache()
    e1 = StreamingEngine(params, CFG, EngineConfig(batch_size=8))
    r1 = e1.simulate(trace)
    s = cache_stats()
    assert s["entries"] >= 1 and s["misses"] >= 1
    hits0 = s["hits"]
    e2 = StreamingEngine(params, CFG, EngineConfig(batch_size=8))
    r2 = e2.simulate(trace)                          # same geometry -> hit
    assert cache_stats()["hits"] > hits0
    assert r2.cpi == r1.cpi
    assert clear_step_cache() >= 1
    assert cache_stats()["entries"] == 0


def test_engine_warmup_aot_bit_identical(trace, params):
    ecfg = EngineConfig(batch_size=8)
    lazy = StreamingEngine(params, CFG, ecfg).simulate(trace)
    clear_step_cache()
    eng = StreamingEngine(params, CFG, ecfg)
    entry = eng.warmup(len(trace))
    if jax.process_count() == 1:
        assert entry.aot is not None                 # AOT path active
        assert cache_stats()["aot_compiled"] >= 1
    res = eng.simulate(trace)
    assert res.cpi == lazy.cpi
    assert res.branch_mpki == lazy.branch_mpki
    assert res.l1d_mpki == lazy.l1d_mpki


def test_train_warmup_aot_bit_identical():
    s = Session(CFG, batch_size=8)
    tr = s.capture("dee", 900)
    ds = s.dataset(UARCH_A, [tr])
    lazy = train_tao_impl(CFG, ds, epochs=2, batch_size=8, lr=1e-3, seed=0)
    clear_train_step_cache()
    entry = warmup_train_step(CFG, batch_size=8, lr=1e-3)
    assert entry.aot is not None
    ts = train_cache_stats()
    assert ts["entries"] == 1 and ts["aot_compiled"] == 1
    warm = train_tao_impl(CFG, ds, epochs=2, batch_size=8, lr=1e-3, seed=0)
    assert warm.losses == lazy.losses                # bit-identical through AOT
    # the warmed entry was reused, not rebuilt
    assert train_cache_stats()["hits"] >= 1


# ---------------------------------------------------------------------------
# Digest-keyed sweep dedup + store-backed feature prep
# ---------------------------------------------------------------------------


def test_sweep_digest_dedup_and_store(tmp_path, trace, params):
    st = ArtifactStore(str(tmp_path / "s"))
    jobs = [
        SweepJob("m/a", params, trace),
        SweepJob("m/b", params, trace.copy()),       # equal content, new object
    ]
    rep = TraceSweeper(CFG, EngineConfig(batch_size=8), store=st).run(jobs)
    # content-digest dedup: one extraction serves both jobs
    assert rep.features_extracted == 1
    assert rep.features_from_store == 0
    assert rep.results["m/a"].cpi == rep.results["m/b"].cpi
    # a second sweeper over the same store extracts nothing
    rep2 = TraceSweeper(CFG, EngineConfig(batch_size=8), store=st).run(jobs)
    assert rep2.features_extracted == 0
    assert rep2.features_from_store == 1
    assert rep2.results["m/a"].cpi == rep.results["m/a"].cpi
    assert rep2.stats()["features_from_store"] == 1


# ---------------------------------------------------------------------------
# Session store plumbing (same-process reuse)
# ---------------------------------------------------------------------------


def test_session_store_reuse(tmp_path):
    root = str(tmp_path / "store")
    s1 = Session(CFG, batch_size=8, store=root, compile_cache=False)
    tr1 = s1.capture("dee", 900)
    gt1 = s1.ground_truth(UARCH_A, tr1)
    m1 = s1.train(UARCH_A, [tr1], epochs=1, batch_size=8)
    r1 = m1.simulate(tr1)

    s2 = Session(CFG, batch_size=8, store=root, compile_cache=False)
    tr2 = s2.capture("dee", 900)
    np.testing.assert_array_equal(tr2.functional, tr1.functional)
    assert s2.ground_truth(UARCH_A, tr2) == gt1
    m2 = s2.train(UARCH_A, [tr2], epochs=1, batch_size=8)
    for a, b in zip(jax.tree.leaves(m1.params), jax.tree.leaves(m2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert m2.losses == m1.losses
    assert m2.simulate(tr2).cpi == r1.cpi
    st = s2.store.stats()
    assert st["misses"] == 0 and st["puts"] == 0, st  # fully warm
    assert st["hits"] >= 4


def test_session_train_key_sensitivity(tmp_path):
    """Different recipes must not collide in the params cache."""
    root = str(tmp_path / "store")
    s = Session(CFG, batch_size=8, store=root, compile_cache=False)
    tr = s.capture("dee", 900)
    m1 = s.train(UARCH_A, [tr], epochs=1, batch_size=8)
    m2 = s.train(UARCH_A, [tr], epochs=2, batch_size=8)   # new recipe
    assert m2.steps > m1.steps
    m3 = s.train(UARCH_A, [tr], epochs=1, batch_size=8)   # hit (in-session)
    for a, b in zip(jax.tree.leaves(m1.params), jax.tree.leaves(m3.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Cross-process zero-cold-start (the acceptance test)
# ---------------------------------------------------------------------------

_CHILD = r"""
import json, sys
import numpy as np
from repro.api import Session
from repro.core import FeatureConfig, TaoConfig
from repro.core.features import num_extractions
from repro.engine import xla_cache_counters

cfg = TaoConfig(
    window=9, d_model=16, n_heads=2, n_layers=1, d_ff=32, d_cat=8,
    features=FeatureConfig(n_buckets=64, n_queue=4, n_mem=8),
)
METRICS = ("cpi", "branch_mpki", "l1d_mpki", "cpi_phase")
sess = Session(cfg, batch_size=8, store=sys.argv[1])
tr = sess.capture("dee", 1200)
model = sess.init_model(seed=3)
rep = sess.sweep({"m": model}, {"t": tr}, metrics=METRICS)
res = rep.results["m/t"]
pal = model.simulate(tr, feature_backend="pallas", metrics=METRICS)
print("CHILD:" + json.dumps({
    "cpi": res.cpi,
    "branch_mpki": res.branch_mpki,
    "l1d_mpki": res.l1d_mpki,
    "cpi_phase": np.asarray(res.cpi_phase).tolist(),
    "pallas_cpi": pal.cpi,
    "pallas_branch_mpki": pal.branch_mpki,
    "pallas_l1d_mpki": pal.l1d_mpki,
    "pallas_cpi_phase": np.asarray(pal.cpi_phase).tolist(),
    "xla": xla_cache_counters(),
    "extractions": num_extractions(),
    "sweep_extracted": rep.features_extracted,
    "sweep_from_store": rep.features_from_store,
}))
"""


def _run_child(store_dir: str) -> dict:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"  # subprocess must never probe TPU
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    p = subprocess.run(
        [sys.executable, "-c", _CHILD, store_dir],
        capture_output=True, text=True, timeout=1200, env=env, cwd=ROOT,
    )
    assert p.returncode == 0, p.stderr[-3000:]
    line = [ln for ln in p.stdout.splitlines() if ln.startswith("CHILD:")][-1]
    return json.loads(line[len("CHILD:"):])


def test_cross_process_zero_cold_start(tmp_path):
    """Second process, warm store + persistent compilation cache: 0 XLA
    compiles, 0 host feature extractions, bit-identical CPI / MPKI /
    phase-curve results on both feature backends."""
    store = str(tmp_path / "store")
    cold = _run_child(store)
    warm = _run_child(store)

    # cold process did real work and persisted it
    assert cold["xla"]["misses"] > 0
    assert cold["extractions"] >= 1

    # warm process: every compile request served from disk, zero XLA
    assert warm["xla"]["requests"] > 0
    assert warm["xla"]["misses"] == 0, warm["xla"]
    assert warm["xla"]["hits"] == warm["xla"]["requests"]
    # zero host feature extraction (sweep + simulate both hit the store)
    assert warm["extractions"] == 0
    assert warm["sweep_extracted"] == 0
    assert warm["sweep_from_store"] == 1

    # bit-identical results, scalar and phase curve, on both backends
    for k in (
        "cpi", "branch_mpki", "l1d_mpki", "cpi_phase",
        "pallas_cpi", "pallas_branch_mpki", "pallas_l1d_mpki",
        "pallas_cpi_phase",
    ):
        assert warm[k] == cold[k], k
