"""Substrate tests: functional/detailed simulators, predictors, caches."""
import numpy as np
import pytest

try:  # property-based when available; example-based fallback otherwise
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.uarch import (
    ALL_BENCHMARKS,
    UARCH_A,
    UARCH_C,
    MicroArchConfig,
    enumerate_design_space,
    get_benchmark,
    run_detailed,
    run_functional,
    sample_design_space,
)
from repro.uarch.branch import PREDICTOR_NAMES, make_predictor
from repro.uarch.cache import TLB, Cache
from repro.uarch.isa import KIND_REAL


def test_design_space_size_matches_paper():
    # paper: 184,320 total designs
    assert enumerate_design_space() == 184_320


def test_functional_trace_deterministic():
    prog = get_benchmark("dee")
    a = run_functional(prog, 2000)
    b = run_functional(prog, 2000)
    assert np.array_equal(a, b)


def test_functional_trace_fields():
    prog = get_benchmark("mcf")
    ft = run_functional(prog, 3000)
    assert len(ft) == 3000
    branches = ft[ft["is_branch"]]
    assert len(branches) > 0
    mems = ft[ft["is_mem"]]
    assert len(mems) > 0
    assert (mems["addr"] % 8 == 0).all()  # word-aligned byte addresses
    stores = ft[ft["is_store"]]
    assert (stores["is_mem"]).all()


@pytest.mark.parametrize("name", sorted(ALL_BENCHMARKS))
def test_detailed_runs_all_benchmarks(name):
    prog = get_benchmark(name)
    ft = run_functional(prog, 2500)
    det, summ = run_detailed(prog, ft, UARCH_A)
    real = det[det["kind"] == KIND_REAL]
    assert len(real) == 2500
    assert summ["total_cycles"] > 0
    assert 0.2 < summ["cpi"] < 50


def test_detailed_invariants(dee_traces):
    _, ft, det, summ = dee_traces
    real = det[det["kind"] == KIND_REAL]
    # committed stream matches functional trace exactly
    for f in ("pc", "opcode", "dst", "src1", "src2", "addr"):
        assert np.array_equal(real[f], ft[f][: len(real)])
    # fetch clocks are non-decreasing over the whole fetch stream
    assert (np.diff(det["fetch_clock"]) >= 0).all()
    # fetch latency is the delta of fetch clocks
    assert (det["fetch_lat"][1:] == np.diff(det["fetch_clock"])).all()
    # retire = fetch + exec (paper's retire-clock definition; completion
    # order is out-of-order — in-order ROB drain is modeled separately)
    assert (
        det["retire_clock"] == det["fetch_clock"] + det["exec_lat"]
    ).all()


def test_bigger_cache_fewer_misses():
    prog = get_benchmark("mcf")
    ft = run_functional(prog, 6000)
    small = MicroArchConfig(l1d_size=16 * 1024, l1d_assoc=2)
    big = MicroArchConfig(l1d_size=128 * 1024, l1d_assoc=8)
    _, s_small = run_detailed(prog, ft, small)
    _, s_big = run_detailed(prog, ft, big)
    assert s_big["l1d_mpki"] <= s_small["l1d_mpki"]


def test_better_predictor_fewer_mispredicts():
    prog = get_benchmark("lee")
    ft = run_functional(prog, 6000)
    _, s_local = run_detailed(prog, ft, MicroArchConfig(branch_predictor="Local"))
    _, s_tage = run_detailed(
        prog, ft, MicroArchConfig(branch_predictor="TAGE_SC_L")
    )
    # TAGE should never be dramatically worse than Local on loopy code
    assert s_tage["branch_mpki"] <= s_local["branch_mpki"] * 1.35


def test_wider_machine_not_slower():
    prog = get_benchmark("rom")
    ft = run_functional(prog, 5000)
    _, s_a = run_detailed(prog, ft, UARCH_A)
    _, s_c = run_detailed(prog, ft, UARCH_C)
    assert s_c["cpi"] <= s_a["cpi"] * 1.05


@pytest.mark.parametrize("name", PREDICTOR_NAMES)
def test_predictor_learns_biased_branch(name):
    bp = make_predictor(name)
    correct = 0
    for _i in range(500):
        pred = bp.predict(0x400)
        taken = True  # always-taken branch
        correct += pred == taken
        bp.update(0x400, taken)
    assert correct / 500 > 0.9


def test_predictor_alternating_pattern():
    # local history predictors learn period-2 patterns
    for name in ("Local", "Tournament", "TAGE_SC_L"):
        bp = make_predictor(name)
        correct = 0
        for i in range(600):
            taken = bool(i % 2)
            pred = bp.predict(0x800)
            if i > 100:
                correct += pred == taken
            bp.update(0x800, taken)
        assert correct / 500 > 0.85, name


def test_cache_lru_eviction():
    c = Cache(size_bytes=2 * 64, assoc=2)  # 1 set, 2 ways
    assert not c.access(0)        # miss
    assert not c.access(64)       # miss (other line)
    assert c.access(0)            # hit
    assert not c.access(128)      # evicts LRU (line 64)
    assert c.access(0)            # still resident
    assert not c.access(64)       # was evicted


def test_tlb_hits_within_page():
    t = TLB(entries=4)
    assert not t.access(0)
    assert t.access(8)
    assert t.access(4000)
    assert not t.access(4096)


def _check_design_point_simulates(seed):
    cfg = sample_design_space(1, seed=seed)[0]
    prog = get_benchmark("xal")
    ft = run_functional(prog, 1200)
    det, summ = run_detailed(prog, ft, cfg)
    real = det[det["kind"] == KIND_REAL]
    assert len(real) == 1200
    assert summ["total_cycles"] == int(real["retire_clock"].max())
    assert (det["exec_lat"] > 0).all()


if HAVE_HYPOTHESIS:
    test_random_design_points_simulate = settings(
        max_examples=10, deadline=None
    )(given(st.integers(0, 10_000))(_check_design_point_simulates))
else:
    test_random_design_points_simulate = pytest.mark.parametrize(
        "seed", [0, 7, 99, 1234, 5678, 9999]
    )(_check_design_point_simulates)
