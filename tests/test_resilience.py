"""Chaos suite for the PR-9 resilience layer.

Every fault class the harness models is driven end to end through the
real production paths (no mocks): deterministic fault injection
(`repro.resilience.faults`) arms named sites inside the store, engine,
sweep scheduler, trace server, and TCP front end, and the tests assert
the documented failure semantics — transient faults retry to a
bit-identical success, poison traces are isolated by batch bisection and
quarantined, hung dispatches expire against their deadline without
wedging the server, repeated hard failures trip the per-model/geometry
circuit breaker (and its cooldown recovers), SIGKILL-style interruptions
of sweeps and training resume from progress manifests with zero
redundant work and bit-identical results, and a hostile TCP peer gets a
structured error plus a clean close, never a stack trace.
"""
from __future__ import annotations

import asyncio
import json
import os
import time

import jax
import numpy as np
import pytest

from repro.api import (
    ArtifactStore,
    ModelRegistry,
    ServeError,
    ServeRequest,
    ServeResult,
    Session,
    TraceServer,
    TrainedModel,
)
from repro.core import FeatureConfig, TaoConfig, init_tao
from repro.core.transfer import train_tao_impl
from repro.engine import EngineConfig
from repro.engine.scheduler import SweepJob, TraceSweeper
from repro.launch.serve import serve_forever
from repro.resilience import (
    CircuitBreaker,
    FaultError,
    FaultPlan,
    FaultSpec,
    RetryPolicy,
    fault_point,
    inject,
    is_transient,
)
from repro.serve import encode_trace
from repro.serve.types import ERROR_CODES
from repro.store import content_key
from repro.uarch import UARCH_A

CFG = TaoConfig(
    window=9, d_model=16, n_heads=2, n_layers=1, d_ff=32, d_cat=8,
    features=FeatureConfig(n_buckets=64, n_queue=4, n_mem=8),
)


@pytest.fixture(scope="module")
def sess():
    return Session(CFG)


@pytest.fixture(scope="module")
def traces(sess):
    # long/mid share the w9 geometry bucket; extra is a third distinct
    # digest in the same bucket (bisection tests need cohabitants)
    return {
        "long": sess.capture("mcf", 1200),
        "mid": sess.capture("dee", 600),
        "extra": sess.capture("mcf", 300),
    }


@pytest.fixture(scope="module")
def models():
    return {
        name: TrainedModel(
            params=init_tao(jax.random.PRNGKey(i), CFG), cfg=CFG, name=name
        )
        for i, name in enumerate(("base", "tuned"))
    }


@pytest.fixture()
def registry(models):
    reg = ModelRegistry()
    for name, m in models.items():
        reg.register(name, m)
    return reg


def _serve(coro):
    return asyncio.run(coro)


def _same_metrics(a, b) -> bool:
    return set(a) == set(b) and all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a
    )


# ---------------------------------------------------------------------------
# Harness: deterministic firing rules, arming discipline
# ---------------------------------------------------------------------------


def test_fault_spec_after_times_and_match():
    plan = FaultPlan(FaultSpec("site.a", after=2, times=2, message="boom"))
    fired = []
    with inject(plan):
        for i in range(6):
            try:
                fault_point("site.a", payload=f"p{i}")
                fired.append(False)
            except FaultError as e:
                fired.append(True)
                assert e.site == "site.a" and e.transient
                assert "boom" in str(e)
        fault_point("site.b")                     # unarmed site: no-op
    assert fired == [False, False, True, True, False, False]
    assert plan.hits == {"site.a": 6, "site.b": 1}
    assert [site for site, _, _ in plan.fired] == ["site.a", "site.a"]

    plan2 = FaultPlan(
        FaultSpec("s", match="poison", times=None, transient=False)
    )
    with inject(plan2):
        fault_point("s", payload="healthy-digest")       # no match, no fire
        with pytest.raises(FaultError) as ei:
            fault_point("s", payload="poison-digest")
        assert not ei.value.transient
    fault_point("s", payload="poison-digest")     # disarmed after the block


def test_fault_plan_seeded_probability_deterministic():
    def fire_seq(seed):
        plan = FaultPlan(FaultSpec("s", p=0.5, times=None), seed=seed)
        out = []
        with inject(plan):
            for _ in range(64):
                try:
                    fault_point("s")
                    out.append(0)
                except FaultError:
                    out.append(1)
        return out

    assert fire_seq(3) == fire_seq(3)             # same seed, same chaos
    assert 0 < sum(fire_seq(3)) < 64
    assert fire_seq(3) != fire_seq(4)


def test_fault_delay_kind_sleeps_instead_of_raising():
    plan = FaultPlan(FaultSpec("s", kind="delay", delay_s=0.05))
    with inject(plan):
        t0 = time.perf_counter()
        fault_point("s")                          # sleeps, does not raise
        assert time.perf_counter() - t0 >= 0.04
        fault_point("s")                          # times=1: second hit clean


def test_inject_non_reentrant_and_none_passthrough():
    fault_point("anything")                       # unarmed: free no-op
    with inject(None):                            # None plan: pass-through
        fault_point("anything")
    with inject(FaultPlan(FaultSpec("s"))):
        with pytest.raises(RuntimeError, match="already injected"):
            with inject(FaultPlan()):
                pass
    with inject(FaultPlan()):                     # released after exit
        pass


def test_fault_plan_from_env(monkeypatch):
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    assert FaultPlan.from_env() is None
    monkeypatch.setenv("REPRO_FAULT_PLAN", json.dumps({
        "seed": 9,
        "faults": [{"site": "serve.dispatch", "times": 2, "exc": "OSError"}],
    }))
    plan = FaultPlan.from_env()
    assert plan.seed == 9
    assert plan.faults[0].site == "serve.dispatch"
    assert plan.faults[0].times == 2 and plan.faults[0].exc == "OSError"
    # the exception vocabulary is closed (env plans cannot name arbitrary
    # types) and the kind vocabulary is checked
    with pytest.raises(ValueError, match="unknown fault exception"):
        FaultSpec("s", exc="SystemExit")
    with pytest.raises(ValueError, match="kind"):
        FaultSpec("s", kind="explode")


def test_retry_policy_schedule_and_classifier():
    rp = RetryPolicy(max_attempts=4, base_delay_s=0.01, multiplier=2.0,
                     max_delay_s=0.03)
    assert rp.delay(1) == pytest.approx(0.01)
    assert rp.delay(2) == pytest.approx(0.02)
    assert rp.delay(3) == pytest.approx(0.03)     # capped
    assert rp.delay(4) == pytest.approx(0.03)
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    assert is_transient(FaultError("s", transient=True))
    assert not is_transient(FaultError("s", transient=False))
    assert is_transient(OSError("flaky"))
    assert is_transient(ConnectionResetError())
    assert is_transient(TimeoutError())
    assert not is_transient(ValueError("poison"))


def test_circuit_breaker_state_machine():
    t = [0.0]
    br = CircuitBreaker(failure_threshold=2, cooldown_s=1.0,
                        clock=lambda: t[0])
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert br.state == "closed" and br.allow()
    br.record_failure()                           # threshold: trips open
    assert br.state == "open" and br.trips == 1
    assert not br.allow()
    assert br.retry_after_s == pytest.approx(1.0)
    t[0] = 1.5
    assert br.allow()                             # half-open: one probe
    assert not br.allow()                         # second probe is shed
    br.record_failure()                           # probe failed: re-open
    assert br.state == "open" and br.trips == 2
    t[0] = 3.0
    assert br.allow()
    br.record_success()
    assert br.state == "closed" and br.failures == 0
    snap = json.loads(json.dumps(br.snapshot()))  # JSON-clean for stats
    assert snap["state"] == "closed" and snap["trips"] == 2
    with pytest.raises(ValueError):
        CircuitBreaker(failure_threshold=0)


# ---------------------------------------------------------------------------
# Server: transient retry, poison bisection, deadlines, breaker
# ---------------------------------------------------------------------------


def test_transient_dispatch_fault_retries_to_success(registry, traces,
                                                     models):
    plan = FaultPlan(FaultSpec("serve.dispatch", times=2))  # transient

    async def run():
        server = TraceServer(
            registry, batch_size=8,
            retry=RetryPolicy(max_attempts=3, base_delay_s=0.005),
        )
        async with server:
            with inject(plan):
                r = await server.submit(
                    ServeRequest(model="base", trace=traces["long"])
                )
            return r, server.stats()

    r, stats = _serve(run())
    assert isinstance(r, ServeResult)
    assert stats.retries == 2 and stats.completed == 1 and stats.failed == 0
    direct = models["base"].simulate(traces["long"], batch_size=8)
    assert _same_metrics(r.metrics, direct.metrics)  # retry is bit-identical


def test_transient_extract_fault_retries_without_poisoning_cache(
        registry, traces, models):
    plan = FaultPlan(FaultSpec("serve.extract", times=1, exc="OSError"))

    async def run():
        server = TraceServer(
            registry, batch_size=8,
            retry=RetryPolicy(max_attempts=3, base_delay_s=0.005),
        )
        async with server:
            with inject(plan):
                r1 = await server.submit(
                    ServeRequest(model="base", trace=traces["mid"])
                )
            # the failed extraction future must not stay cached: a second
            # request for the same digest extracts (or coalesces) cleanly
            r2 = await server.submit(
                ServeRequest(model="tuned", trace=traces["mid"])
            )
            return r1, r2, server.stats()

    r1, r2, stats = _serve(run())
    assert stats.retries >= 1 and stats.failed == 0
    direct = models["base"].simulate(traces["mid"], batch_size=8)
    assert _same_metrics(r1.metrics, direct.metrics)
    assert r2.num_instructions == direct.num_instructions


def test_poison_trace_bisected_quarantined_cohabitants_unharmed(
        registry, traces, models):
    poison = traces["mid"]
    plan = FaultPlan(FaultSpec(
        "serve.dispatch", match=poison.digest, times=None,
        transient=False, exc="ValueError",
    ))

    async def run():
        server = TraceServer(registry, batch_size=8, group_size=4)
        async with server:
            with inject(plan):
                futs = [
                    server.submit(ServeRequest(model="base", trace=tr))
                    for tr in (traces["long"], poison, traces["extra"])
                ]
                out = await asyncio.gather(*futs, return_exceptions=True)
                # the quarantined digest is shed at admission on resubmit
                with pytest.raises(ServeError) as ei:
                    server.submit(ServeRequest(model="base", trace=poison))
                assert ei.value.code == "TRACE_REJECTED"
                # and the server keeps serving other traces
                again = await server.submit(
                    ServeRequest(model="base", trace=traces["extra"])
                )
            return out, again, server.stats()

    (r_long, r_poison, r_extra), again, stats = _serve(run())
    assert isinstance(r_poison, ServeError)
    assert r_poison.code == "TRACE_REJECTED"
    assert stats.quarantined == 1 and stats.bisections >= 1
    assert stats.retries == 0                     # poison is never retried
    # cohabitants of the poisoned dispatch group re-ran bit-identically
    for r, key in ((r_long, "long"), (r_extra, "extra"), (again, "extra")):
        direct = models["base"].simulate(traces[key], batch_size=8)
        assert _same_metrics(r.metrics, direct.metrics)


def test_deadline_exceeded_on_hung_dispatch_then_recovers(registry, traces):
    # one dispatch hangs well past the request deadline: the request fails
    # DEADLINE_EXCEEDED, the hung pool is abandoned, and the very next
    # request is served on a fresh dispatch thread
    plan = FaultPlan(FaultSpec(
        "serve.dispatch", kind="delay", delay_s=0.8, times=1,
    ))

    async def run():
        server = TraceServer(registry, batch_size=8)
        async with server:
            with inject(plan):
                with pytest.raises(ServeError) as ei:
                    await server.submit(ServeRequest(
                        model="base", trace=traces["long"], deadline_s=0.15,
                    ))
                assert ei.value.code == "DEADLINE_EXCEEDED"
                r = await server.submit(
                    ServeRequest(model="base", trace=traces["extra"])
                )
            return r, server.stats()

    r, stats = _serve(run())
    assert stats.deadline_exceeded == 1
    assert stats.completed == 1 and isinstance(r, ServeResult)


def test_deadline_spent_in_queue_expires_without_dispatch(registry, traces):
    async def run():
        server = TraceServer(registry, batch_size=8, deadline_s=0.0)
        async with server:
            with pytest.raises(ServeError) as ei:
                await server.submit(
                    ServeRequest(model="base", trace=traces["extra"])
                )
            assert ei.value.code == "DEADLINE_EXCEEDED"
            # a per-request deadline overrides the server default
            r = await server.submit(ServeRequest(
                model="base", trace=traces["extra"], deadline_s=30.0,
            ))
            return r, server.stats()

    r, stats = _serve(run())
    assert stats.deadline_exceeded == 1 and stats.completed == 1


def test_breaker_trips_sheds_and_recovers_after_cooldown(registry, traces,
                                                         models):
    # 4 injected failures = 2 requests x 2 attempts: both exhaust their
    # retries, which is exactly the breaker threshold
    plan = FaultPlan(FaultSpec("serve.dispatch", times=4, transient=True))

    async def run():
        server = TraceServer(
            registry, batch_size=8,
            retry=RetryPolicy(max_attempts=2, base_delay_s=0.002),
            breaker_threshold=2, breaker_cooldown_s=0.25,
        )
        async with server:
            with inject(plan):
                for _ in range(2):
                    with pytest.raises(ServeError) as ei:
                        await server.submit(ServeRequest(
                            model="base", trace=traces["long"],
                        ))
                    assert ei.value.code == "INTERNAL"
                # breaker open: admissions shed with a backoff hint
                with pytest.raises(ServeError) as ei:
                    server.submit(
                        ServeRequest(model="base", trace=traces["long"])
                    )
                assert ei.value.code == "CIRCUIT_OPEN"
                assert ei.value.retry_after_s is not None
                assert ei.value.retry_after_s > 0
                open_stats = server.stats()
                # cooldown elapses; the half-open probe succeeds (the plan
                # is exhausted) and closes the breaker
                await asyncio.sleep(0.3)
                r = await server.submit(
                    ServeRequest(model="base", trace=traces["long"])
                )
            return open_stats, r, server.stats()

    open_stats, r, stats = _serve(run())
    assert open_stats.breaker_sheds == 1 and open_stats.retries == 2
    assert open_stats.breakers["base/w9b8"]["state"] == "open"
    assert stats.breakers["base/w9b8"]["state"] == "closed"
    direct = models["base"].simulate(traces["long"], batch_size=8)
    assert _same_metrics(r.metrics, direct.metrics)
    # the new counters are part of the JSON wire contract
    sd = json.loads(json.dumps(stats.to_dict()))
    for k in ("retries", "deadline_exceeded", "quarantined", "bisections",
              "breaker_sheds", "breakers"):
        assert k in sd, k
    assert sd["breakers"]["base/w9b8"]["trips"] == 1


def test_chaos_smoke_mixed_load_stays_available(registry, traces):
    """The CI chaos-smoke entry: under REPRO_FAULT_PLAN (or a default
    transient-fault plan) every request either completes or fails with a
    stable ServeError code, the books balance, and the server serves
    clean traffic afterwards."""
    plan = FaultPlan.from_env() or FaultPlan(
        FaultSpec("serve.dispatch", times=2),
        FaultSpec("serve.extract", times=1, exc="OSError"),
        seed=7,
    )

    async def run():
        server = TraceServer(
            registry, batch_size=8,
            retry=RetryPolicy(max_attempts=3, base_delay_s=0.005),
        )
        async with server:
            with inject(plan):
                futs = [
                    server.submit(ServeRequest(
                        model=("base", "tuned")[i % 2],
                        trace=traces[("long", "mid", "extra")[i % 3]],
                        tenant=f"t{i % 3}",
                    ))
                    for i in range(6)
                ]
                out = await asyncio.gather(*futs, return_exceptions=True)
            # plan disarmed: the server must serve clean traffic
            r = await server.submit(
                ServeRequest(model="base", trace=traces["extra"])
            )
            return out, r, server.stats()

    out, r, stats = _serve(run())
    assert sum(plan.hits.values()) > 0            # the chaos actually ran
    for item in out:
        if isinstance(item, BaseException):
            assert isinstance(item, ServeError), item
            assert item.code in ERROR_CODES
        else:
            assert isinstance(item, ServeResult)
    assert isinstance(r, ServeResult)
    assert stats.admitted == stats.completed + stats.failed


# ---------------------------------------------------------------------------
# Shutdown racing in-flight work
# ---------------------------------------------------------------------------


def test_shutdown_drain_serves_admitted_but_unbatched(registry, traces):
    async def run():
        server = TraceServer(registry, batch_size=8)
        await server.start()
        futs = [
            server.submit(ServeRequest(model="base", trace=traces["extra"],
                                       request_id=f"d{i}"))
            for i in range(3)
        ]
        # shutdown races the admitted-but-unbatched requests: drain=True
        # must serve every one of them before the loop exits
        await server.shutdown(drain=True)
        return await asyncio.gather(*futs), server.stats()

    results, stats = _serve(run())
    assert all(isinstance(r, ServeResult) for r in results)
    assert stats.completed == 3 and stats.failed == 0


def test_shutdown_drain_waits_for_parked_retry(registry, traces):
    plan = FaultPlan(FaultSpec("serve.dispatch", times=1))

    async def run():
        server = TraceServer(
            registry, batch_size=8,
            retry=RetryPolicy(max_attempts=3, base_delay_s=0.05),
        )
        await server.start()
        with inject(plan):
            fut = server.submit(
                ServeRequest(model="base", trace=traces["extra"])
            )
            # the drain loop must wait out the backoff timer, not exit
            # while the retry is parked on it
            await server.shutdown(drain=True)
            r = await fut
        return r, server.stats()

    r, stats = _serve(run())
    assert isinstance(r, ServeResult)
    assert stats.retries == 1 and stats.failed == 0


def test_shutdown_kill_fails_parked_retry_with_stable_code(registry, traces):
    plan = FaultPlan(FaultSpec("serve.dispatch", times=None, transient=True))

    async def run():
        server = TraceServer(
            registry, batch_size=8,
            retry=RetryPolicy(max_attempts=10, base_delay_s=0.2),
        )
        await server.start()
        with inject(plan):
            fut = server.submit(
                ServeRequest(model="base", trace=traces["extra"])
            )
            await server.stop(drain=False)
            with pytest.raises(ServeError) as ei:
                await fut
            assert ei.value.code == "SHUTTING_DOWN"

    _serve(run())


# ---------------------------------------------------------------------------
# Sweeper: producer death, crash-resume manifests
# ---------------------------------------------------------------------------


def test_sweeper_producer_thread_death_surfaces_no_hang(traces):
    params = init_tao(jax.random.PRNGKey(4), CFG)
    jobs = [
        SweepJob("m/a", params, traces["long"].functional),
        SweepJob("m/b", params, traces["mid"].functional),
    ]
    sweeper = TraceSweeper(
        CFG, EngineConfig(batch_size=8), async_prepare=True,
    )
    plan = FaultPlan(FaultSpec("scheduler.prepare", exc="RuntimeError"))
    with inject(plan), pytest.raises(RuntimeError, match="injected fault"):
        sweeper.run(jobs)


def test_sweep_resume_skips_done_jobs_bit_identical(tmp_path, traces):
    st = ArtifactStore(str(tmp_path / "s"))
    p1 = init_tao(jax.random.PRNGKey(5), CFG)
    p2 = init_tao(jax.random.PRNGKey(6), CFG)
    t1 = traces["long"].functional
    t2 = traces["mid"].functional

    def jobs():
        return [
            SweepJob("m1/a", p1, t1), SweepJob("m1/b", p1, t2),
            SweepJob("m2/a", p2, t1), SweepJob("m2/b", p2, t2),
        ]

    ref = TraceSweeper(CFG, EngineConfig(batch_size=8)).run(jobs())

    # "SIGKILL" mid-sweep: the 3rd consume dies after 2 jobs published
    plan = FaultPlan(FaultSpec(
        "scheduler.consume", after=2, times=1, exc="RuntimeError",
    ))
    crashed = TraceSweeper(CFG, EngineConfig(batch_size=8), store=st)
    with inject(plan), pytest.raises(RuntimeError, match="injected fault"):
        crashed.run(jobs(), resume_key="dse-run")

    # resume: the done set loads from manifests; only the remainder runs,
    # and its features come from the store (0 redundant extractions)
    resumed = TraceSweeper(CFG, EngineConfig(batch_size=8), store=st).run(
        jobs(), resume_key="dse-run"
    )
    assert resumed.jobs_skipped == 2
    assert resumed.features_extracted == 0
    assert resumed.num_traces == 4
    assert set(resumed.results) == {"m1/a", "m1/b", "m2/a", "m2/b"}
    for key, r in ref.results.items():
        assert _same_metrics(r.metrics, resumed.results[key].metrics), key

    # a fully-complete resume is pure manifest replay: no device work at all
    replay = TraceSweeper(CFG, EngineConfig(batch_size=8), store=st).run(
        jobs(), resume_key="dse-run"
    )
    assert replay.jobs_skipped == 4
    assert replay.num_compiles == 0 and replay.features_extracted == 0
    for key, r in ref.results.items():
        assert _same_metrics(r.metrics, replay.results[key].metrics), key

    with pytest.raises(ValueError, match="store"):
        TraceSweeper(CFG, EngineConfig(batch_size=8)).run(
            jobs(), resume_key="no-store"
        )


# ---------------------------------------------------------------------------
# Training: crash-resume manifests, bit-identical trajectories
# ---------------------------------------------------------------------------


def test_train_resume_bit_identical(tmp_path):
    s = Session(CFG, batch_size=8)
    tr = s.capture("dee", 900)
    ds = s.dataset(UARCH_A, [tr])
    base = train_tao_impl(CFG, ds, epochs=3, batch_size=8, lr=1e-3, seed=0)

    st = ArtifactStore(str(tmp_path / "ck"))
    # "crash" after epoch 0: run one epoch with manifests on
    part = train_tao_impl(CFG, ds, epochs=1, batch_size=8, lr=1e-3, seed=0,
                          store=st, resume_key="run")
    assert part.losses == base.losses[:1]

    # resume to 3 epochs: losses, params, and step count all match the
    # uninterrupted run exactly (shuffle rng state resumes mid-stream)
    resumed = train_tao_impl(CFG, ds, epochs=3, batch_size=8, lr=1e-3,
                             seed=0, store=st, resume_key="run")
    assert resumed.losses == base.losses
    assert resumed.steps == base.steps
    for a, b in zip(jax.tree.leaves(base.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # re-running a finished recipe replays the final manifest: zero epochs
    again = train_tao_impl(CFG, ds, epochs=3, batch_size=8, lr=1e-3,
                           seed=0, store=st, resume_key="run")
    assert again.losses == base.losses and again.steps == base.steps
    for a, b in zip(jax.tree.leaves(base.params),
                    jax.tree.leaves(again.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    with pytest.raises(ValueError, match="manifest_every"):
        train_tao_impl(CFG, ds, epochs=1, batch_size=8, store=st,
                       resume_key="run", manifest_every=0)


# ---------------------------------------------------------------------------
# Store: load faults as misses, pin-lease idempotence, dead-pid sweep
# ---------------------------------------------------------------------------


def test_store_load_fault_is_corruption_miss_then_recovers(tmp_path):
    st = ArtifactStore(str(tmp_path / "s"))
    key = content_key("features", "z")
    st.put("features", key, {"x": np.arange(4.0)})
    with inject(FaultPlan(FaultSpec("store.load", times=1, exc="OSError"))):
        assert st.get("features", key) is None    # fault -> miss, never raise
    assert st.counters["corrupt_dropped"] == 1
    assert st.put("features", key, {"x": np.arange(4.0)})  # recompute+reput
    tree, _ = st.get("features", key)
    np.testing.assert_array_equal(tree["x"], np.arange(4.0))


def test_store_load_fault_sweep_recovers_bit_identical(tmp_path, traces):
    st = ArtifactStore(str(tmp_path / "s"))
    params = init_tao(jax.random.PRNGKey(7), CFG)

    def jobs():
        return [SweepJob("m/t", params, traces["mid"].functional)]

    warm = TraceSweeper(CFG, EngineConfig(batch_size=8), store=st).run(jobs())
    assert warm.features_extracted == 1
    # the warm store entry "corrupts" on load: the sweep re-extracts and
    # the result is bit-identical
    with inject(FaultPlan(FaultSpec("store.load", times=1))):
        rep = TraceSweeper(CFG, EngineConfig(batch_size=8), store=st).run(
            jobs()
        )
    assert rep.features_extracted == 1 and rep.features_from_store == 0
    assert st.counters["corrupt_dropped"] == 1
    assert _same_metrics(warm.results["m/t"].metrics,
                         rep.results["m/t"].metrics)


def test_store_pin_lease_double_release_idempotent(tmp_path):
    st = ArtifactStore(str(tmp_path / "s"))
    k = content_key("features", "p")
    st.put("features", k, {"x": np.arange(3.0)})
    edir = st._entry_dir("features", k)

    def pins():
        return [n for n in os.listdir(edir) if n.startswith(".pin-")]

    with st.pin("features", k) as lease:
        assert lease and len(pins()) == 1
        lease.release()                           # early release
        assert pins() == []
        lease.release()                           # double-unpin: no-op
        assert pins() == []
    assert pins() == []                           # context exit: still a no-op
    st.gc(max_age_s=0.0)
    assert not st.has("features", k)              # nothing left blocking GC


def test_store_plain_gc_sweeps_dead_pid_pins(tmp_path):
    # regression: a SIGKILLed reader's pin marker must not survive even a
    # no-pressure gc() (no byte budget, no age bound)
    st = ArtifactStore(str(tmp_path / "s"))
    k = content_key("features", "held")
    st.put("features", k, {"x": np.arange(3.0)})
    edir = st._entry_dir("features", k)
    open(os.path.join(edir, ".pin-999999999-3"), "x").close()
    out = st.gc()                                 # no eviction pressure at all
    assert out["stale_pins"] == 1
    assert st.counters["stale_pins_swept"] == 1
    assert not [n for n in os.listdir(edir) if n.startswith(".pin-")]
    assert st.has("features", k)                  # the entry itself survives


# ---------------------------------------------------------------------------
# TCP front end: hostile input gets structured errors + clean closes
# ---------------------------------------------------------------------------


def test_tcp_oversized_line_structured_error_and_close(registry):
    async def run():
        server = TraceServer(registry, batch_size=8)
        async with server:
            ready = asyncio.get_running_loop().create_future()
            tcp = asyncio.get_running_loop().create_task(
                serve_forever(server, "127.0.0.1", 0, ready,
                              max_line_bytes=1024))
            _, port = await ready
            reader, writer = await asyncio.open_connection("127.0.0.1", port)
            writer.write(b"x" * 4096 + b"\n")
            await writer.drain()
            resp = json.loads(await reader.readline())
            eof = await reader.readline()
            writer.close()
            tcp.cancel()
        return resp, eof

    resp, eof = _serve(run())
    assert resp["ok"] is False and resp["error"] == "BAD_REQUEST"
    assert "line" in resp["message"]
    assert eof == b""                             # server closed cleanly


def test_tcp_truncated_request_structured_error(registry):
    async def run():
        server = TraceServer(registry, batch_size=8)
        async with server:
            ready = asyncio.get_running_loop().create_future()
            tcp = asyncio.get_running_loop().create_task(
                serve_forever(server, "127.0.0.1", 0, ready))
            _, port = await ready
            reader, writer = await asyncio.open_connection("127.0.0.1", port)
            writer.write(b'{"op": "stats"')       # no newline, then EOF
            await writer.drain()
            writer.write_eof()
            resp = json.loads(await reader.readline())
            eof = await reader.readline()
            writer.close()
            tcp.cancel()
        return resp, eof

    resp, eof = _serve(run())
    assert resp["ok"] is False and resp["error"] == "BAD_REQUEST"
    assert "truncated" in resp["message"]
    assert eof == b""


def test_tcp_disconnect_mid_request_server_survives(registry, traces):
    async def run():
        server = TraceServer(registry, batch_size=8)
        async with server:
            ready = asyncio.get_running_loop().create_future()
            tcp = asyncio.get_running_loop().create_task(
                serve_forever(server, "127.0.0.1", 0, ready))
            _, port = await ready

            # tenant 1 fires a simulate and slams the connection shut: the
            # reply hits a dead socket (fault-boundary), nothing leaks
            r1, w1 = await asyncio.open_connection("127.0.0.1", port)
            w1.write(json.dumps({
                "op": "simulate", "model": "base",
                "trace": encode_trace(traces["extra"].functional),
            }).encode() + b"\n")
            await w1.drain()
            w1.transport.abort()

            # tenant 2 on a fresh connection is unaffected
            r2, w2 = await asyncio.open_connection("127.0.0.1", port)
            w2.write(b'{"op": "stats"}\n')
            await w2.drain()
            resp = json.loads(await r2.readline())
            w2.close()
            tcp.cancel()
        return resp, server.stats()

    resp, stats = _serve(run())
    assert resp["ok"] is True and "stats" in resp
    assert stats.admitted >= 1                    # the aborted request ran


def test_tcp_reply_fault_drops_only_that_response(registry):
    plan = FaultPlan(FaultSpec("tcp.reply", times=1,
                               exc="ConnectionResetError"))

    async def run():
        server = TraceServer(registry, batch_size=8)
        async with server:
            ready = asyncio.get_running_loop().create_future()
            tcp = asyncio.get_running_loop().create_task(
                serve_forever(server, "127.0.0.1", 0, ready))
            _, port = await ready
            reader, writer = await asyncio.open_connection("127.0.0.1", port)
            with inject(plan):
                writer.write(b'{"op": "models"}\n')  # reply write faults
                writer.write(b'{"op": "models"}\n')  # this one lands
                await writer.drain()
                resp = json.loads(await reader.readline())
            # the connection is still healthy after the dropped reply
            writer.write(b'{"op": "stats"}\n')
            await writer.drain()
            resp2 = json.loads(await reader.readline())
            writer.close()
            tcp.cancel()
        return resp, resp2

    resp, resp2 = _serve(run())
    assert resp["ok"] is True and resp["models"] == ["base", "tuned"]
    assert resp2["ok"] is True and "stats" in resp2
