"""Fig. 15 — hardware design-space exploration with Tao: L1D-size sweep
(cache MPKI) and branch-predictor sweep (branch MPKI), prediction vs the
detailed simulator's ground truth."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import train_tao
from repro.engine import EngineConfig, StreamingEngine
from repro.uarch import UARCH_B, MicroArchConfig

from .common import (
    EPOCHS,
    TEST_BENCHES,
    TRAIN_BENCHES,
    adjusted_dataset,
    emit,
    ground_truth,
    tao_config,
)


def _engine_for(uarch):
    """Train a model for the design point and wrap it in a streaming engine
    (one compile, reused across every benchmark simulated on this point)."""
    cfg = tao_config()
    ds = adjusted_dataset(uarch, TRAIN_BENCHES[:2])
    res = train_tao(cfg, ds, epochs=max(3, EPOCHS // 2), batch_size=16, lr=1e-3)
    return StreamingEngine(res.params, cfg, EngineConfig(batch_size=64))


def run() -> None:
    # Fig 15a: L1 D-cache size sweep — does predicted MPKI track the truth?
    truth_curve, pred_curve = [], []
    for size_kb in (16, 32, 128):
        ua = dataclasses.replace(
            UARCH_B, l1d_size=size_kb * 1024, name=f"l1d{size_kb}"
        )
        engine = _engine_for(ua)
        t_mpki, p_mpki = [], []
        for bench in TEST_BENCHES[:2]:
            ft, truth = ground_truth(ua, bench)
            sim = engine.simulate(ft)
            t_mpki.append(truth["l1d_mpki"])
            p_mpki.append(sim.l1d_mpki)
        truth_curve.append(float(np.mean(t_mpki)))
        pred_curve.append(float(np.mean(p_mpki)))
        emit(
            f"fig15a/l1d={size_kb}KB",
            0.0,
            f"truth_l1d_mpki={truth_curve[-1]:.2f};tao_l1d_mpki={pred_curve[-1]:.2f}",
        )
    mono_truth = all(np.diff(truth_curve) <= 1e-9)
    mono_pred = all(np.diff(pred_curve) <= max(1.0, 0.1 * pred_curve[0]))
    emit("fig15a/trend", 0.0,
         f"truth_monotone={mono_truth};tao_tracks_trend={mono_pred}")

    # Fig 15b: branch predictor sweep
    for bp in ("Local", "BiMode", "Tournament"):
        ua = dataclasses.replace(UARCH_B, branch_predictor=bp, name=f"bp{bp}")
        engine = _engine_for(ua)
        t_mpki, p_mpki = [], []
        for bench in TEST_BENCHES[:2]:
            ft, truth = ground_truth(ua, bench)
            sim = engine.simulate(ft)
            t_mpki.append(truth["branch_mpki"])
            p_mpki.append(sim.branch_mpki)
        emit(
            f"fig15b/bp={bp}",
            0.0,
            f"truth_br_mpki={np.mean(t_mpki):.2f};tao_br_mpki={np.mean(p_mpki):.2f}",
        )
