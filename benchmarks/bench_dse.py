"""Fig. 15 — hardware design-space exploration with Tao: L1D-size sweep
(cache MPKI) and branch-predictor sweep (branch MPKI), prediction vs the
detailed simulator's ground truth — plus the async multi-trace sweep
scheduler's tracked perf numbers (``run_sweep``; ROADMAP "async multi-trace
scheduling")."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.api import DesignSpace, TrainedModel
from repro.uarch import UARCH_B

from .common import (
    EPOCHS,
    TEST_BENCHES,
    TEST_LEN,
    TRAIN_BENCHES,
    Timer,
    adjusted_dataset,
    emit,
    session,
)


def _model_for(uarch) -> TrainedModel:
    """Train a model for the design point; its engines come from the
    process-wide step cache, so every design point of the sweep reuses one
    compiled executable."""
    sess = session()
    ds = adjusted_dataset(uarch, TRAIN_BENCHES[:2])
    return sess.train(
        dataset=ds, epochs=max(3, EPOCHS // 2), batch_size=16, lr=1e-3,
        name=uarch.name, uarch=uarch,
    )


def run() -> None:
    sess = session()
    # Fig 15a: L1 D-cache size sweep — does predicted MPKI track the truth?
    truth_curve, pred_curve = [], []
    for size_kb in (16, 32, 128):
        ua = dataclasses.replace(
            UARCH_B, l1d_size=size_kb * 1024, name=f"l1d{size_kb}"
        )
        model = _model_for(ua)
        t_mpki, p_mpki = [], []
        for bench in TEST_BENCHES[:2]:
            tr = sess.capture(bench, TEST_LEN)
            truth = sess.ground_truth(ua, tr)
            sim = model.simulate(tr)
            t_mpki.append(truth["l1d_mpki"])
            p_mpki.append(sim.l1d_mpki)
        truth_curve.append(float(np.mean(t_mpki)))
        pred_curve.append(float(np.mean(p_mpki)))
        emit(
            f"fig15a/l1d={size_kb}KB",
            0.0,
            f"truth_l1d_mpki={truth_curve[-1]:.2f};tao_l1d_mpki={pred_curve[-1]:.2f}",
        )
    mono_truth = all(np.diff(truth_curve) <= 1e-9)
    mono_pred = all(np.diff(pred_curve) <= max(1.0, 0.1 * pred_curve[0]))
    emit("fig15a/trend", 0.0,
         f"truth_monotone={mono_truth};tao_tracks_trend={mono_pred}")

    # Fig 15b: branch predictor sweep
    for bp in ("Local", "BiMode", "Tournament"):
        ua = dataclasses.replace(UARCH_B, branch_predictor=bp, name=f"bp{bp}")
        model = _model_for(ua)
        t_mpki, p_mpki = [], []
        for bench in TEST_BENCHES[:2]:
            tr = sess.capture(bench, TEST_LEN)
            truth = sess.ground_truth(ua, tr)
            sim = model.simulate(tr)
            t_mpki.append(truth["branch_mpki"])
            p_mpki.append(sim.branch_mpki)
        emit(
            f"fig15b/bp={bp}",
            0.0,
            f"truth_br_mpki={np.mean(t_mpki):.2f};tao_br_mpki={np.mean(p_mpki):.2f}",
        )


def run_sweep() -> None:
    """Async multi-trace DSE sweep (Session.sweep): 4 design points x 2
    traces through one shared executable, vs the same jobs run one-by-one
    through single-trace engines (per-trace host prep on the critical
    path)."""
    sess = session()
    space = DesignSpace.vary(
        UARCH_B, "l1d_size", [kb * 1024 for kb in (16, 32, 64, 128)],
        name_fmt="l1d{value}",
    )
    models = {ua.name: _model_for(ua) for ua in space}
    traces = {b: sess.capture(b, TEST_LEN) for b in TEST_BENCHES[:2]}

    # warm the shared step once so BOTH paths below measure steady-state
    # throughput (neither is charged the one-off XLA compile)
    first = next(iter(models.values()))
    first.simulate(next(iter(traces.values())), batch_size=sess.batch_size)

    # baseline: the single-trace engine path, sequential over the same jobs
    # (per-trace host feature prep repeats per model on the critical path).
    # Best-of-N on both paths: the structural deltas are a few percent at
    # tiny scale, so single runs drown in 2-core scheduler noise.
    reps = 3
    seq_secs, n_seq = float("inf"), 0
    for _ in range(reps):
        with Timer() as t_seq:
            n_seq = 0
            for model in models.values():
                for tr in traces.values():
                    n_seq += model.simulate(
                        tr, batch_size=sess.batch_size
                    ).num_instructions
        seq_secs = min(seq_secs, t_seq.seconds)
    seq_mips = n_seq / 1e6 / seq_secs
    seq_tps = len(models) * len(traces) / seq_secs

    report = None
    for _ in range(reps):
        r = sess.sweep(models, traces)
        # the cache is warm, so the sweep itself must compile nothing
        assert r.num_compiles == 0, r.num_compiles
        if report is None or r.seconds < report.seconds:
            report = r
    emit(
        "sweep/scheduler",
        1e6 * report.seconds / report.num_traces,
        f"uarchs={len(models)};traces={len(traces)};"
        f"traces_per_s={report.traces_per_s:.2f};sweep_mips={report.mips:.4f};"
        f"single_engine_mips={seq_mips:.4f};single_engine_traces_per_s={seq_tps:.2f};"
        f"speedup={report.mips / seq_mips:.2f}x;"
        f"compiles={report.num_compiles};"
        f"queue_occupancy_mean={report.queue_occupancy_mean:.2f};"
        f"queue_occupancy_max={report.queue_occupancy_max};"
        f"queue_depth={report.queue_depth};"
        f"prepared_async={report.prepared_async}",
    )
    # predictions from the sweep match the single-engine path exactly
    for name, model in models.items():
        for tb, tr in traces.items():
            a = report.results[f"{name}/{tb}"]
            b = model.simulate(tr, batch_size=sess.batch_size)
            assert a.cpi == b.cpi and a.l1d_mpki == b.l1d_mpki, (name, tb)


# ---------------------------------------------------------------------------
# Cold-start benchmark: first-result latency with and without the
# persistent caches (artifact store + JAX compilation cache).
# ---------------------------------------------------------------------------

_COLDSTART_MARK = "COLDSTART_JSON:"


def _coldstart_workload(store_dir: str, t_spawn: float) -> None:
    """The child process body: one previously-declared sweep geometry,
    warmed up, captured, simulated.  Prints a JSON record tagged
    ``COLDSTART_JSON:`` for the parent."""
    import json
    import time

    from repro.api import Session
    from repro.core.features import num_extractions
    from repro.engine import xla_cache_counters

    from .common import TEST_LEN, tao_config

    t_session = time.time()
    sess = Session(tao_config(), store=store_dir)
    # declare the geometry set up front: sim step AND train step compile
    # (or, warm, deserialize) before any trace exists
    sess.warmup([TEST_LEN], train=True)
    model = sess.init_model(seed=7)
    tr = sess.capture("mcf", TEST_LEN)
    res = model.simulate(tr)
    first = time.time()
    rep = sess.sweep({"m": model}, {"t": tr})
    out = {
        # what the caches can address: Session construction -> first metric
        "cold_start_to_first_result_s": first - t_session,
        # process-inclusive variant (interpreter + jax import overhead
        # rides in both cold and warm, diluting the ratio)
        "spawn_to_first_result_s": first - t_spawn,
        "total_s": time.time() - t_spawn,
        "cpi": res.cpi,
        "l1d_mpki": res.l1d_mpki,
        "branch_mpki": res.branch_mpki,
        "xla": xla_cache_counters(),
        "features_extracted": num_extractions(),
        "sweep_features_extracted": rep.features_extracted,
        "sweep_features_from_store": rep.features_from_store,
        "store": sess.store.stats(),
    }
    print(_COLDSTART_MARK + json.dumps(out), flush=True)


def run_coldstart() -> None:
    """Run the identical workload in two fresh subprocesses against one
    store: the first pays every cost (feature extraction, detailed sim,
    XLA), the second must hit the artifact store and deserialize every
    executable.  Emits before/after ``cold_start_to_first_result_s`` and
    stores the full records in the --json artifact (``coldstart`` key)."""
    import json
    import os
    import shutil
    import subprocess
    import sys
    import tempfile
    import time

    from .common import SCALE, emit, set_extra

    root = tempfile.mkdtemp(prefix="repro-coldstart-")
    store = os.path.join(root, "store")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    def child():
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(repo, "src"), env.get("PYTHONPATH", "")]
        ).rstrip(os.pathsep)
        env.setdefault("BENCH_SCALE", SCALE)
        code = (
            "from benchmarks.bench_dse import _coldstart_workload; "
            f"_coldstart_workload({store!r}, {time.time()!r})"
        )
        p = subprocess.run(
            [sys.executable, "-c", code],
            capture_output=True, text=True, cwd=repo, env=env, timeout=1800,
        )
        if p.returncode != 0:
            raise RuntimeError(
                f"coldstart child failed:\n{p.stdout[-2000:]}\n{p.stderr[-4000:]}"
            )
        line = [
            ln for ln in p.stdout.splitlines() if ln.startswith(_COLDSTART_MARK)
        ][-1]
        return json.loads(line[len(_COLDSTART_MARK):])

    try:
        cold = child()
        warm = child()
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # correctness first: the warm process must reproduce the cold one's
    # metrics bit-for-bit from cached artifacts
    for k in ("cpi", "l1d_mpki", "branch_mpki"):
        assert warm[k] == cold[k], (k, warm[k], cold[k])
    assert warm["xla"]["misses"] == 0, warm["xla"]
    assert warm["xla"]["requests"] > 0, warm["xla"]
    assert warm["features_extracted"] == 0, warm["features_extracted"]

    before = cold["cold_start_to_first_result_s"]
    after = warm["cold_start_to_first_result_s"]
    speedup = before / max(after, 1e-9)
    emit(
        "coldstart/cold", before * 1e6,
        f"first_result_s={before:.2f};xla_misses={cold['xla']['misses']};"
        f"extractions={cold['features_extracted']}",
    )
    emit(
        "coldstart/warm", after * 1e6,
        f"first_result_s={after:.2f};xla_misses={warm['xla']['misses']};"
        f"xla_hits={warm['xla']['hits']};extractions=0",
    )
    emit(
        "coldstart/speedup", 0.0,
        f"cold_start_to_first_result_s_before={before:.2f};"
        f"cold_start_to_first_result_s_after={after:.2f};"
        f"speedup={speedup:.1f}x;"
        f"spawn_to_first_before={cold['spawn_to_first_result_s']:.2f};"
        f"spawn_to_first_after={warm['spawn_to_first_result_s']:.2f}",
    )
    set_extra(
        "coldstart",
        {
            "cold_start_to_first_result_s_before": before,
            "cold_start_to_first_result_s_after": after,
            "speedup": speedup,
            "cold": cold,
            "warm": warm,
        },
    )
