"""Fig. 9 — simulation accuracy vs the SimNet baseline.

Trains Tao (multi-metric, functional-trace inputs) and SimNet (CNN,
detailed-trace inputs) on the train benchmarks for each µarch and compares
per-benchmark CPI error against the detailed simulator's ground truth.

Doubles as the int8 accuracy-parity gate: every trained (µarch, bench)
pair is re-simulated with ``precision="int8"`` and must stay within 5%
CPI relative / max(10%, 5.0) MPKI of fp32 — the suite FAILS otherwise
(``fig9/int8_parity`` records the observed band).  CPI is regression-
derived and robust under quantization (observed 0-4.8% across trained
small-scale checkpoints); the MPKIs count argmax class decisions, so logit
perturbations near decision boundaries move them in whole-event steps —
the wider band is the honest sensitivity of those metrics, matching
``tests/test_fused.py``.  At
``BENCH_SCALE=tiny`` (smoke: 2 epochs, trends only) the band is
reported but not enforced — under-trained checkpoints put the argmax
latency/dlevel decisions at coin-flip margins, which is exactly the
regime quantization error flips; the gate's claim is about checkpoints
trained to the geometry's full epoch budget.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core.align import build_adjusted_trace
from repro.core.simnet import (
    SimNetConfig,
    init_simnet,
    make_simnet_step,
    simnet_features,
    simnet_forward,
    simnet_windows,
)
from repro.train.optim import AdamWConfig, adamw_init
from repro.uarch import UARCH_A, UARCH_B, UARCH_C, get_benchmark, run_detailed, run_functional

from .common import (
    EPOCHS,
    SCALE,
    TEST_BENCHES,
    TEST_LEN,
    TRACE_LEN,
    TRAIN_BENCHES,
    Timer,
    adjusted_dataset,
    emit,
    ground_truth,
    session,
    tao_config,
)


def _train_simnet(uarch, window):
    cfg = SimNetConfig(window=window)
    feats = []
    for b in TRAIN_BENCHES:
        prog = get_benchmark(b)
        ft = run_functional(prog, TRACE_LEN)
        det, _ = run_detailed(prog, ft, uarch)
        al = build_adjusted_trace(det)
        feats.append(simnet_features(al.adjusted))
    x = np.concatenate([f["x"] for f in feats])
    labels = np.concatenate([f["labels"] for f in feats])
    ds = simnet_windows({"x": x, "labels": labels}, window)
    params = init_simnet(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = make_simnet_step(cfg, AdamWConfig(lr=1e-3))
    rng = np.random.default_rng(0)
    n = len(ds["x"])
    for _ep in range(EPOCHS):
        order = rng.permutation(n)
        for lo in range(0, n - 8 + 1, 8):
            idx = order[lo : lo + 8]
            batch = {"x": jnp.asarray(ds["x"][idx]), "labels": jnp.asarray(ds["labels"][idx])}
            params, opt, loss = step(params, opt, batch)
    return cfg, params


def _simnet_cpi(cfg, params, uarch, bench):
    """SimNet needs the µarch-specific detailed trace as INPUT."""
    prog = get_benchmark(bench)
    ft = run_functional(prog, TEST_LEN)
    det, _ = run_detailed(prog, ft, uarch)
    al = build_adjusted_trace(det)
    feats = simnet_features(al.adjusted)
    ds = simnet_windows(feats, cfg.window)
    preds = []
    fwd = jax.jit(lambda p, x: simnet_forward(p, x, cfg))
    for lo in range(0, len(ds["x"]), 32):
        out = fwd(params, jnp.asarray(ds["x"][lo : lo + 32]))
        preds.append(np.asarray(out, np.float32))
    from repro.core.model import LAT_SCALE

    lat = np.maximum(np.concatenate(preds).reshape(-1, 2), 0.0) * LAT_SCALE
    total = lat[:, 0].sum() + lat[-1, 1]
    return total / len(lat)


def run() -> None:
    cfg = tao_config()
    results = []
    int8_errs = []
    for uarch in (UARCH_A, UARCH_B, UARCH_C):
        ds = adjusted_dataset(uarch, TRAIN_BENCHES)
        with Timer() as t_tao:
            model = session().train(dataset=ds, epochs=EPOCHS, batch_size=16, lr=1e-3)
        with Timer() as t_sn:
            sn_cfg, sn_params = _train_simnet(uarch, cfg.window)
        for bench in TEST_BENCHES:
            ft, truth = ground_truth(uarch, bench)
            sim = model.simulate(ft, collect=True)
            tao_err = sim.error_vs(truth["cpi"])
            sn_cpi = _simnet_cpi(sn_cfg, sn_params, uarch, bench)
            sn_err = abs(sn_cpi - truth["cpi"]) / truth["cpi"] * 100
            results.append((uarch.name, bench, tao_err, sn_err))
            emit(
                f"fig9/{uarch.name}-{bench}",
                sim.seconds * 1e6,
                f"tao_err={tao_err:.1f}%;simnet_err={sn_err:.1f}%;truth_cpi={truth['cpi']:.3f};tao_cpi={sim.cpi:.3f}",
            )
            # int8 parity gate: on a TRAINED checkpoint the W8A8 path must
            # track fp32 within 5% CPI relative and max(5%, 1.0) MPKI
            # absolute — the engine acceptance band for precision="int8"
            sim8 = model.simulate(ft, precision="int8")
            q_err = abs(sim8.cpi - sim.cpi) / max(sim.cpi, 1e-9)
            enforce = SCALE != "tiny"  # see docstring: smoke reports only
            assert not enforce or q_err <= 0.05, (
                f"int8 CPI parity broken on {uarch.name}/{bench}: "
                f"{sim8.cpi:.4f} vs fp32 {sim.cpi:.4f} ({q_err:.1%})"
            )
            for mname in ("branch_mpki", "l1d_mpki"):
                a, b = sim8.metrics[mname], sim.metrics[mname]
                assert not enforce or abs(a - b) <= max(0.10 * b, 5.0), (
                    f"int8 {mname} parity broken on {uarch.name}/{bench}: "
                    f"{a:.3f} vs fp32 {b:.3f}"
                )
            int8_errs.append(q_err)
    tao_avg = float(np.mean([r[2] for r in results]))
    sn_avg = float(np.mean([r[3] for r in results]))
    emit("fig9/avg", 0.0, f"tao_avg_err={tao_avg:.2f}%;simnet_avg_err={sn_avg:.2f}%")
    emit(
        "fig9/int8_parity", 0.0,
        f"max_cpi_rel_err={max(int8_errs):.2e};"
        f"mean_cpi_rel_err={float(np.mean(int8_errs)):.2e};"
        f"gate={'pass' if SCALE != 'tiny' else 'report-only(tiny)'}",
    )
