"""MIPS regression gate over committed benchmark baselines.

``python -m benchmarks.run --json BENCH_timing.json`` emits rows whose
``derived`` field carries ``<label>_mips=<value>`` throughput numbers
(engine, fused megakernel, int8, feature extraction...).  This module
diffs a fresh run against the checked-in baseline
(``benchmarks/baselines/BENCH_timing.json``, generated at
``BENCH_SCALE=tiny`` — the CI bench-smoke geometry) and FAILS when any
throughput dropped below ``baseline * (1 - tolerance)``.

CI runs it right after the table4 smoke::

    python -m benchmarks.check_regression BENCH_timing.json

The default tolerance is wide (50%) because CI runners are shared,
noisy machines — the gate catches structural regressions (a lost
compile-cache hit, an accidental host round-trip, a dead fast path),
not single-digit jitter.  Override with ``--tolerance`` or
``$REPRO_BENCH_TOLERANCE``; refresh the baseline with ``--update``
after an intentional perf-relevant change (commit the result).

Throughputs that only exist on one side are reported but never fail the
gate: new rows have no baseline yet, and retired rows are the updater's
job to prune.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import shutil
import sys
from typing import Dict

BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "baselines", "BENCH_timing.json"
)

# <label>_mips=<float> fragments inside a row's derived field
_MIPS_RE = re.compile(r"([A-Za-z0-9_]+_mips)=([0-9.eE+-]+)")


def extract_mips(payload: Dict) -> Dict[str, float]:
    """``{"<row>/<label>_mips": value}`` for every throughput a bench
    JSON artifact recorded."""
    out: Dict[str, float] = {}
    for row in payload.get("rows", []):
        for label, val in _MIPS_RE.findall(row.get("derived", "")):
            out[f"{row['name']}/{label}"] = float(val)
    return out


def check(
    current_path: str,
    baseline_path: str = BASELINE,
    tolerance: float = 0.5,
) -> int:
    with open(current_path) as f:
        current = extract_mips(json.load(f))
    if not os.path.exists(baseline_path):
        print(f"no baseline at {baseline_path}; run with --update to seed it")
        return 1
    with open(baseline_path) as f:
        base_payload = json.load(f)
    baseline = extract_mips(base_payload)
    if base_payload.get("scale") is not None:
        with open(current_path) as f:
            cur_scale = json.load(f).get("scale")
        if cur_scale != base_payload["scale"]:
            print(
                f"scale mismatch: baseline={base_payload['scale']!r} "
                f"current={cur_scale!r} — numbers are not comparable "
                f"(regenerate the baseline at the same BENCH_SCALE)"
            )
            return 1

    failures = 0
    for key in sorted(set(baseline) | set(current)):
        b, c = baseline.get(key), current.get(key)
        if b is None:
            print(f"  NEW      {key}: {c:.4f} (no baseline)")
            continue
        if c is None:
            print(f"  MISSING  {key}: baseline {b:.4f}, absent from this run")
            continue
        floor = b * (1.0 - tolerance)
        status = "ok" if c >= floor else "REGRESSION"
        print(
            f"  {status:<10} {key}: {c:.4f} vs baseline {b:.4f} "
            f"(floor {floor:.4f})"
        )
        failures += status != "ok"
    if failures:
        print(
            f"{failures} throughput(s) below baseline*(1-{tolerance}); "
            "if intentional, refresh with --update and commit the baseline"
        )
        return 1
    print(f"all {len(baseline)} baselined throughputs within tolerance")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="fresh BENCH_*.json from benchmarks.run --json")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument(
        "--tolerance",
        type=float,
        default=float(os.environ.get("REPRO_BENCH_TOLERANCE", "0.5")),
        help="allowed fractional drop below baseline (default 0.5; "
        "env REPRO_BENCH_TOLERANCE)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="copy the current artifact over the baseline instead of checking",
    )
    args = ap.parse_args()
    if args.update:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline updated: {args.baseline}")
        return
    sys.exit(check(args.current, args.baseline, args.tolerance))


if __name__ == "__main__":
    main()
