"""Table 4 + Fig. 10 — end-to-end time decomposition and trace economics.

  * functional vs detailed trace generation throughput (Fig 10b; paper: ~25x)
  * squashed/nop composition of the detailed-trace surplus (Fig 10a)
  * simulation (inference) throughput: streaming engine vs the pre-refactor
    host batch loop (`simulate_trace_legacy`), with the engine's compile
    count asserted to be exactly one
  * the Table-4 ratio: (trace gen + train + simulate) Tao vs SimNet, where
    SimNet is charged detailed-trace generation for every new µarch and Tao
    is charged the reusable functional trace once.
"""
from __future__ import annotations

import numpy as np

from repro.core import train_tao
from repro.core.simulate import simulate_trace_legacy
from repro.engine import EngineConfig, StreamingEngine
from repro.uarch import UARCH_A, UARCH_B, UARCH_C, get_benchmark, run_detailed, run_functional
from repro.uarch.isa import KIND_NOP, KIND_REAL, KIND_SQUASHED

from .common import (
    EPOCHS,
    TEST_BENCHES,
    TRACE_LEN,
    TRAIN_BENCHES,
    Timer,
    adjusted_dataset,
    emit,
    tao_config,
)


def run() -> None:
    # --- Fig 10b: trace generation throughput ---------------------------
    func_mips, det_mips = [], []
    sq_frac, nop_frac = [], []
    for bench in TRAIN_BENCHES:
        prog = get_benchmark(bench)
        with Timer() as tf:
            ft = run_functional(prog, TRACE_LEN)
        for uarch in (UARCH_A, UARCH_B, UARCH_C):
            with Timer() as td:
                det, summ = run_detailed(prog, ft, uarch)
            func_mips.append(TRACE_LEN / tf.seconds / 1e6)
            det_mips.append(TRACE_LEN / td.seconds / 1e6)
            kinds = det["kind"]
            extra = (kinds != KIND_REAL).sum()
            if extra:
                sq_frac.append((kinds == KIND_SQUASHED).sum() / extra)
                nop_frac.append((kinds == KIND_NOP).sum() / extra)
    f_mips = float(np.mean(func_mips))
    d_mips = float(np.mean(det_mips))
    ratio = f_mips / d_mips
    emit(
        "fig10b/trace_gen",
        1e6 / (f_mips * 1e6),
        f"functional_mips={f_mips:.3f};detailed_mips={d_mips:.3f};speedup={ratio:.1f}x(paper:25.2x)",
    )
    emit(
        "fig10a/trace_surplus",
        0.0,
        f"squashed_frac={np.mean(sq_frac)*100:.1f}%;nop_frac={np.mean(nop_frac)*100:.1f}%(paper:97.0/3.0)",
    )

    # --- Table 4: overall time, Tao vs SimNet ---------------------------
    cfg = tao_config()
    # Tao: functional trace (once) + transfer-style short training + sim
    prog = get_benchmark("dee")
    with Timer() as t_func:
        ft = run_functional(prog, TRACE_LEN)
    ds = adjusted_dataset(UARCH_A, TRAIN_BENCHES)
    with Timer() as t_train_short:
        res = train_tao(cfg, ds.subsample(max(16, len(ds) // 4)), epochs=max(2, EPOCHS // 3),
                        batch_size=16, lr=1e-3)
    engine = StreamingEngine(res.params, cfg, EngineConfig(batch_size=64))
    with Timer() as t_sim:
        ft_test = run_functional(get_benchmark("mcf"), TRACE_LEN // 2)
        sim = engine.simulate(ft_test)
    tao_total = t_func.seconds + t_train_short.seconds + t_sim.seconds

    # --- engine vs pre-refactor simulate loop (the 18.06x claim's lever) --
    legacy = simulate_trace_legacy(res.params, ft_test, cfg)
    sim2 = engine.simulate(ft_test)  # warm engine: steady-state throughput
    assert engine.num_compiles == 1, engine.num_compiles
    cpi_err = abs(sim2.cpi - legacy.cpi) / max(legacy.cpi, 1e-9)
    emit(
        "engine/sim_throughput",
        1e6 / max(sim2.mips * 1e6, 1e-9),
        f"engine_mips={sim2.mips:.4f};legacy_mips={legacy.mips:.4f};"
        f"speedup={sim2.mips / legacy.mips:.2f}x;compiles={engine.num_compiles};"
        f"cpi_rel_err={cpi_err:.2e}",
    )

    # SimNet-style: detailed trace for the new µarch + full training + sim
    with Timer() as t_det:
        run_detailed(prog, ft, UARCH_B)
    with Timer() as t_train_full:
        train_tao(cfg, ds, epochs=EPOCHS, batch_size=16, lr=1e-3)
    simnet_total = t_det.seconds + t_train_full.seconds + t_sim.seconds
    emit(
        "table4/overall",
        tao_total * 1e6,
        f"tao_s={tao_total:.1f};simnet_style_s={simnet_total:.1f};"
        f"speedup={simnet_total/tao_total:.2f}x(paper:18.06x at 10B-instr scale);"
        f"sim_mips={sim.mips:.4f}",
    )
