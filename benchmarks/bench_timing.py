"""Table 4 + Fig. 10 — end-to-end time decomposition and trace economics.

  * functional vs detailed trace generation throughput (Fig 10b; paper: ~25x)
  * squashed/nop composition of the detailed-trace surplus (Fig 10a)
  * simulation (inference) throughput: streaming engine vs the pre-refactor
    host batch loop (`simulate_trace_legacy`), with the engine's compile
    count asserted to be exactly one
  * §4.2 feature-extraction throughput: host NumPy (`extract_features`) vs
    the device Pallas scan kernels (`extract_features_device`), plus the
    fused engine (`feature_backend="pallas"`) vs the host pre-pass
  * the trace->logits megakernel (`feature_backend="fused"`, asserted
    bit-identical to the staged path) and the int8 W8A8 engine, each with
    end-to-end MIPS and host->device bytes/instr (the committed baseline
    `benchmarks/baselines/BENCH_timing.json` + `check_regression` gate
    these rows in CI)
  * the Table-4 ratio: (trace gen + train + simulate) Tao vs SimNet, where
    SimNet is charged detailed-trace generation for every new µarch and Tao
    is charged the reusable functional trace once.
"""
from __future__ import annotations

import numpy as np

from repro.core import extract_features
from repro.core.simulate import simulate_trace_legacy
from repro.kernels.features.ops import extract_features_device
from repro.uarch import UARCH_A, UARCH_B, UARCH_C, get_benchmark, run_detailed, run_functional
from repro.uarch.isa import KIND_NOP, KIND_REAL, KIND_SQUASHED

from .common import (
    EPOCHS,
    TRACE_LEN,
    TRAIN_BENCHES,
    Timer,
    adjusted_dataset,
    emit,
    session,
    tao_config,
)


def run() -> None:
    # --- Fig 10b: trace generation throughput ---------------------------
    func_mips, det_mips = [], []
    sq_frac, nop_frac = [], []
    for bench in TRAIN_BENCHES:
        prog = get_benchmark(bench)
        with Timer() as tf:
            ft = run_functional(prog, TRACE_LEN)
        for uarch in (UARCH_A, UARCH_B, UARCH_C):
            with Timer() as td:
                det, summ = run_detailed(prog, ft, uarch)
            func_mips.append(TRACE_LEN / tf.seconds / 1e6)
            det_mips.append(TRACE_LEN / td.seconds / 1e6)
            kinds = det["kind"]
            extra = (kinds != KIND_REAL).sum()
            if extra:
                sq_frac.append((kinds == KIND_SQUASHED).sum() / extra)
                nop_frac.append((kinds == KIND_NOP).sum() / extra)
    f_mips = float(np.mean(func_mips))
    d_mips = float(np.mean(det_mips))
    ratio = f_mips / d_mips
    emit(
        "fig10b/trace_gen",
        1e6 / (f_mips * 1e6),
        f"functional_mips={f_mips:.3f};detailed_mips={d_mips:.3f};speedup={ratio:.1f}x(paper:25.2x)",
    )
    emit(
        "fig10a/trace_surplus",
        0.0,
        f"squashed_frac={np.mean(sq_frac)*100:.1f}%;nop_frac={np.mean(nop_frac)*100:.1f}%(paper:97.0/3.0)",
    )

    # --- Table 4: overall time, Tao vs SimNet ---------------------------
    cfg = tao_config()
    sess = session()
    # Tao: functional trace (once) + transfer-style short training + sim
    prog = get_benchmark("dee")
    with Timer() as t_func:
        ft = run_functional(prog, TRACE_LEN)
    ds = adjusted_dataset(UARCH_A, TRAIN_BENCHES)
    with Timer() as t_train_short:
        model = sess.train(
            dataset=ds.subsample(max(16, len(ds) // 4)),
            epochs=max(2, EPOCHS // 3), batch_size=16, lr=1e-3,
        )
    engine = model.engine(batch_size=64)
    with Timer() as t_sim:
        ft_test = sess.capture("mcf", TRACE_LEN // 2).functional
        sim = engine.simulate(ft_test)
    tao_total = t_func.seconds + t_train_short.seconds + t_sim.seconds

    # --- engine vs pre-refactor simulate loop (the 18.06x claim's lever) --
    legacy = simulate_trace_legacy(model.params, ft_test, cfg)
    sim2 = engine.simulate(ft_test)  # warm engine: steady-state throughput
    assert engine.num_compiles == 1, engine.num_compiles
    cpi_err = abs(sim2.cpi - legacy.cpi) / max(legacy.cpi, 1e-9)
    emit(
        "engine/sim_throughput",
        1e6 / max(sim2.mips * 1e6, 1e-9),
        f"engine_mips={sim2.mips:.4f};legacy_mips={legacy.mips:.4f};"
        f"speedup={sim2.mips / legacy.mips:.2f}x;compiles={engine.num_compiles};"
        f"cpi_rel_err={cpi_err:.2e}",
    )

    # --- host vs device feature extraction (Pallas feature kernels) -------
    fcfg = cfg.features
    extract_features_device(ft_test, fcfg)  # warm-up: compile the scans
    with Timer() as t_host:
        extract_features(ft_test, fcfg, with_labels=False)
    with Timer() as t_dev:
        extract_features_device(ft_test, fcfg)  # includes device->host copy
    n_ft = len(ft_test)
    host_mips = n_ft / 1e6 / t_host.seconds
    dev_mips = n_ft / 1e6 / t_dev.seconds
    # fused engine: features computed on device inside the streaming step
    fused = model.engine(batch_size=64, feature_backend="pallas")
    fused.simulate(ft_test)       # warm-up
    sim_fused = fused.simulate(ft_test)
    # host->device traffic: the numpy backend ships the materialized
    # FeatureSet (+ masks); the pallas backend ships raw int32/bool columns.
    host_bpi = 4 * (1 + 32 + 5 + fcfg.n_queue + fcfg.n_mem) + 2
    dev_bpi = 4 * 6 + 4  # 6 int32 columns + 4 bool columns (trace_columns)
    emit(
        "features/extraction",
        1e6 / max(dev_mips * 1e6, 1e-9),
        f"host_mips={host_mips:.4f};device_mips={dev_mips:.4f};"
        f"device_speedup={dev_mips / host_mips:.2f}x;"
        f"fused_engine_mips={sim_fused.mips:.4f};"
        f"host_prepass_engine_mips={sim2.mips:.4f};"
        f"transfer_bytes_per_instr={host_bpi}->{dev_bpi}"
        f"({host_bpi / dev_bpi:.1f}x less)",
    )

    # --- fused megakernel backend + int8 quantized path -------------------
    # Same raw-column payload as the staged backend (dev_bpi), but features
    # never materialize in HBM: one megakernel launch per batch feeds the
    # step directly.  fp32 fused is bit-identical to staged by contract.
    mega = model.engine(batch_size=64, feature_backend="fused")
    mega.simulate(ft_test)        # warm-up
    sim_mega = mega.simulate(ft_test)
    assert sim_mega.cpi == sim_fused.cpi, (sim_mega.cpi, sim_fused.cpi)
    q8 = model.engine(batch_size=64, feature_backend="fused", precision="int8")
    q8.simulate(ft_test)          # warm-up (own step: precision is keyed)
    sim_q8 = q8.simulate(ft_test)
    q8_err = abs(sim_q8.cpi - sim_mega.cpi) / max(sim_mega.cpi, 1e-9)
    emit(
        "fused/megakernel",
        1e6 / max(sim_mega.mips * 1e6, 1e-9),
        f"fused_mips={sim_mega.mips:.4f};int8_mips={sim_q8.mips:.4f};"
        f"staged_mips={sim_fused.mips:.4f};"
        f"int8_cpi_rel_err={q8_err:.2e};"
        f"transfer_bytes_per_instr={dev_bpi}",
    )

    # SimNet-style: detailed trace for the new µarch + full training + sim
    with Timer() as t_det:
        run_detailed(prog, ft, UARCH_B)
    with Timer() as t_train_full:
        sess.train(dataset=ds, epochs=EPOCHS, batch_size=16, lr=1e-3)
    simnet_total = t_det.seconds + t_train_full.seconds + t_sim.seconds
    emit(
        "table4/overall",
        tao_total * 1e6,
        f"tao_s={tao_total:.1f};simnet_style_s={simnet_total:.1f};"
        f"speedup={simnet_total/tao_total:.2f}x(paper:18.06x at 10B-instr scale);"
        f"sim_mips={sim.mips:.4f}",
    )
