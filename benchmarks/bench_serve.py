"""Serving benchmark: open-loop multi-tenant load against TraceServer.

Mirrors the serving story the paper's throughput claims imply: a warm
server (AOT-warmed executables + shared feature pre-passes) absorbing
Poisson arrivals from several tenants across mixed geometries and models.
Reports p50/p99 end-to-end latency, sustained traces/s, and the batch
fill ratio — the numbers CI tracks per PR via ``BENCH_serve.json``.

Open-loop means arrivals do not wait for completions (the honest way to
measure a queueing system): a seeded exponential schedule fires
``submit`` on its own clock; QUEUE_FULL rejections honor the server's
``retry_after_s`` hint and are counted, not hidden.
"""
from __future__ import annotations

import asyncio
import random

import jax
import numpy as np

from repro.api import (
    ModelRegistry,
    ServeError,
    ServeRequest,
    TraceServer,
    TrainedModel,
)
from repro.core import init_tao

from .common import SCALE, TEST_LEN, Timer, emit, session, set_extra, tao_config

# offered load: requests per second per tenant (open loop), total requests
_N_REQUESTS = {"tiny": 24, "small": 64}.get(SCALE, 128)
_TENANTS = ("alice", "bob", "carol", "dave")


def _build():
    cfg = tao_config()
    s = session()
    traces = [
        s.capture("mcf", TEST_LEN),
        s.capture("dee", max(cfg.window * 3, TEST_LEN // 2)),
        s.capture("lee", max(2, cfg.window // 2)),   # second geometry
    ]
    registry = ModelRegistry()
    for i, name in enumerate(("base", "tuned")):
        registry.register(name, TrainedModel(
            params=init_tao(jax.random.PRNGKey(i), cfg), cfg=cfg, name=name))
    return registry, traces


async def _open_loop(server, traces, n_requests, rate_per_s):
    """Fire ``n_requests`` per tenant on an exponential arrival clock;
    returns (results, rejections)."""
    results, rejections = [], 0

    async def tenant(name, seed):
        nonlocal rejections
        r = random.Random(seed)
        pending = []
        for i in range(n_requests):
            await asyncio.sleep(r.expovariate(rate_per_s))
            req = ServeRequest(
                model=("base", "tuned")[i % 2],
                trace=traces[r.randrange(len(traces))],
                tenant=name,
            )
            try:
                pending.append(server.submit(req))
            except ServeError as e:
                assert e.code == "QUEUE_FULL"
                rejections += 1
                await asyncio.sleep(e.retry_after_s or 0.01)
                try:
                    pending.append(server.submit(req))
                except ServeError:
                    rejections += 1          # dropped after one retry
        results.extend(await asyncio.gather(*pending))

    await asyncio.gather(*(
        tenant(t, seed) for seed, t in enumerate(_TENANTS)
    ))
    return results, rejections


def run() -> None:
    registry, traces = _build()
    per_tenant = max(2, _N_REQUESTS // len(_TENANTS))

    async def drive():
        server = TraceServer(registry, batch_size=8, max_queue=64)
        async with server:
            server.warmup([len(t) for t in traces])
            # calibrate the open-loop rate to ~2x a single closed-loop
            # client's throughput so queues form but do not diverge
            t = Timer()
            with t:
                await server.submit(ServeRequest(model="base",
                                                 trace=traces[0]))
            rate = 2.0 / max(t.seconds, 1e-4) / len(_TENANTS)
            with Timer() as wall:
                results, rejections = await _open_loop(
                    server, traces, per_tenant, rate)
            stats = server.stats()
        return results, rejections, stats, wall.seconds

    results, rejections, stats, wall = asyncio.run(drive())
    lat = np.array([r.total_s for r in results])
    p50, p99 = np.percentile(lat, 50), np.percentile(lat, 99)
    served_per_s = len(results) / wall

    emit("serve/latency_p50", p50 * 1e6, f"n={len(results)}")
    emit("serve/latency_p99", p99 * 1e6,
         f"rejected={rejections} compiles={stats.num_compiles}")
    emit("serve/traces_per_s", 1e6 / served_per_s,
         f"{served_per_s:.1f}/s fill={stats.batch_fill_ratio:.2f}")
    emit("serve/coalesce", 0.0,
         f"extracted={stats.features_extracted} "
         f"coalesced={stats.features_coalesced}")
    set_extra("serve", {
        "latency_p50_s": float(p50),
        "latency_p99_s": float(p99),
        "traces_per_s": float(served_per_s),
        "batch_fill_ratio": stats.batch_fill_ratio,
        "open_loop_rejections": rejections,
        "stats": stats.to_dict(),
    })
