"""Shared benchmark scaffolding.

Every benchmark mirrors one paper table/figure at CPU scale: reduced trace
lengths and model widths (controlled by SCALE), with the paper-facing claim
being the RELATIVE result (ratios, orderings, trends) rather than absolute
A100 wall-clock.  Emits ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

from repro.core import FeatureConfig, TaoConfig, build_windows, extract_features
from repro.core.align import build_adjusted_trace
from repro.core.dataset import WindowDataset, concat_datasets
from repro.uarch import (
    UARCH_A,
    UARCH_B,
    UARCH_C,
    MicroArchConfig,
    get_benchmark,
    run_detailed,
    run_functional,
)

SCALE = os.environ.get("BENCH_SCALE", "small")

if SCALE == "tiny":  # CI smoke: seconds, not minutes; trends only
    TRACE_LEN = 2_000
    TEST_LEN = 1_000
    EPOCHS = 2
    WINDOW = 17
    D_MODEL, N_HEADS, N_LAYERS, D_FF, D_CAT = 32, 2, 1, 64, 16
elif SCALE == "small":
    TRACE_LEN = 12_000
    TEST_LEN = 6_000
    EPOCHS = 6
    WINDOW = 33
    D_MODEL, N_HEADS, N_LAYERS, D_FF, D_CAT = 64, 4, 2, 128, 32
else:  # "full"-ish (still CPU feasible)
    TRACE_LEN = 60_000
    TEST_LEN = 20_000
    EPOCHS = 15
    WINDOW = 65
    D_MODEL, N_HEADS, N_LAYERS, D_FF, D_CAT = 128, 4, 3, 256, 64

FEATURES = FeatureConfig(n_buckets=256, n_queue=8, n_mem=16)

TRAIN_BENCHES = ["dee", "rom", "nab", "lee"]
TEST_BENCHES = ["mcf", "xal", "wrf", "cac"]

_ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def rows() -> List[str]:
    return list(_ROWS)


def tao_config() -> TaoConfig:
    return TaoConfig(
        window=WINDOW,
        d_model=D_MODEL,
        n_heads=N_HEADS,
        n_layers=N_LAYERS,
        d_ff=D_FF,
        d_cat=D_CAT,
        features=FEATURES,
    )


_ds_cache: Dict = {}


def adjusted_dataset(uarch: MicroArchConfig, benches, n=None, features=FEATURES,
                     window=None) -> WindowDataset:
    """Trace -> §4.1 adjusted trace -> windows, cached."""
    n = n or TRACE_LEN
    window = window or WINDOW
    key = (uarch.key(), tuple(benches), n, features, window)
    if key in _ds_cache:
        return _ds_cache[key]
    parts = []
    for b in benches:
        prog = get_benchmark(b)
        ft = run_functional(prog, n)
        det, _ = run_detailed(prog, ft, uarch)
        al = build_adjusted_trace(det)
        parts.append(build_windows(extract_features(al.adjusted, features), window))
    ds = concat_datasets(parts)
    _ds_cache[key] = ds
    return ds


def ground_truth(uarch: MicroArchConfig, bench: str, n=None):
    n = n or TEST_LEN
    prog = get_benchmark(bench)
    ft = run_functional(prog, n)
    det, summ = run_detailed(prog, ft, uarch)
    return ft, summ


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
