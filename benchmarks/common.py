"""Shared benchmark scaffolding.

Every benchmark mirrors one paper table/figure at CPU scale: reduced trace
lengths and model widths (controlled by SCALE), with the paper-facing claim
being the RELATIVE result (ratios, orderings, trends) rather than absolute
A100 wall-clock.  Emits ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

from repro.api import Session
from repro.core import FeatureConfig, TaoConfig
from repro.core.dataset import WindowDataset
from repro.uarch import MicroArchConfig

# geometry_manifest.json is the single source of truth for bench geometry
# (trace lengths, window, model dims per BENCH_SCALE): CI hashes it into
# the actions/cache key for the persistent compilation cache + artifact
# store, so editing a geometry here rolls those caches over in lockstep.
with open(
    os.path.join(os.path.dirname(os.path.abspath(__file__)),
                 "geometry_manifest.json")
) as _f:
    _MANIFEST = json.load(_f)

SCALE = os.environ.get("BENCH_SCALE", "small")
# tiny = CI smoke (seconds, trends only); small = CPU container default;
# anything else = "full"-ish (still CPU feasible)
_G = _MANIFEST.get(SCALE, _MANIFEST["full"])

TRACE_LEN = _G["trace_len"]
TEST_LEN = _G["test_len"]
EPOCHS = _G["epochs"]
WINDOW = _G["window"]
D_MODEL, N_HEADS, N_LAYERS, D_FF, D_CAT = (
    _G["d_model"], _G["n_heads"], _G["n_layers"], _G["d_ff"], _G["d_cat"]
)

FEATURES = FeatureConfig(**_MANIFEST["features"])

TRAIN_BENCHES = ["dee", "rom", "nab", "lee"]
TEST_BENCHES = ["mcf", "xal", "wrf", "cac"]

_ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def rows() -> List[str]:
    return list(_ROWS)


# structured side-channel for --json artifacts: suites drop whole objects
# here (e.g. the coldstart suite's before/after timings) that would not
# survive the CSV row format
_EXTRAS: Dict[str, object] = {}


def set_extra(key: str, value) -> None:
    _EXTRAS[key] = value


def extras() -> Dict[str, object]:
    return dict(_EXTRAS)


def tao_config() -> TaoConfig:
    return TaoConfig(
        window=WINDOW,
        d_model=D_MODEL,
        n_heads=N_HEADS,
        n_layers=N_LAYERS,
        d_ff=D_FF,
        d_cat=D_CAT,
        features=FEATURES,
    )


# Benchmarks drive everything through the repro.api facade.  One Session
# per TaoConfig (the session caches captured traces and adjusted datasets).
_sessions: Dict[TaoConfig, Session] = {}


def session_for(cfg: TaoConfig) -> Session:
    s = _sessions.get(cfg)
    if s is None:
        # $REPRO_STORE attaches a persistent artifact store (and with it
        # the XLA compilation cache) to every bench session — how CI keeps
        # sweep/cold-start smoke warm across runs
        store = os.environ.get("REPRO_STORE")
        s = Session(cfg, store=store) if store else Session(cfg)
        _sessions[cfg] = s
    return s


def session() -> Session:
    """The bench-scale default Session (config from ``tao_config()``)."""
    return session_for(tao_config())


def adjusted_dataset(uarch: MicroArchConfig, benches, n=None, features=FEATURES,
                     window=None) -> WindowDataset:
    """Trace -> §4.1 adjusted trace -> windows (Session-cached)."""
    n = n or TRACE_LEN
    cfg = tao_config()
    if features != cfg.features or (window is not None and window != cfg.window):
        cfg = dataclasses.replace(
            cfg, features=features, window=window or cfg.window
        )
    s = session_for(cfg)
    return s.dataset(uarch, [s.capture(b, n) for b in benches])


def ground_truth(uarch: MicroArchConfig, bench: str, n=None):
    s = session()
    tr = s.capture(bench, n or TEST_LEN)
    return tr.functional, s.ground_truth(uarch, tr)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
