"""Shared benchmark scaffolding.

Every benchmark mirrors one paper table/figure at CPU scale: reduced trace
lengths and model widths (controlled by SCALE), with the paper-facing claim
being the RELATIVE result (ratios, orderings, trends) rather than absolute
A100 wall-clock.  Emits ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List

from repro.api import Session
from repro.core import FeatureConfig, TaoConfig
from repro.core.dataset import WindowDataset
from repro.uarch import MicroArchConfig

SCALE = os.environ.get("BENCH_SCALE", "small")

if SCALE == "tiny":  # CI smoke: seconds, not minutes; trends only
    TRACE_LEN = 2_000
    TEST_LEN = 1_000
    EPOCHS = 2
    WINDOW = 17
    D_MODEL, N_HEADS, N_LAYERS, D_FF, D_CAT = 32, 2, 1, 64, 16
elif SCALE == "small":
    TRACE_LEN = 12_000
    TEST_LEN = 6_000
    EPOCHS = 6
    WINDOW = 33
    D_MODEL, N_HEADS, N_LAYERS, D_FF, D_CAT = 64, 4, 2, 128, 32
else:  # "full"-ish (still CPU feasible)
    TRACE_LEN = 60_000
    TEST_LEN = 20_000
    EPOCHS = 15
    WINDOW = 65
    D_MODEL, N_HEADS, N_LAYERS, D_FF, D_CAT = 128, 4, 3, 256, 64

FEATURES = FeatureConfig(n_buckets=256, n_queue=8, n_mem=16)

TRAIN_BENCHES = ["dee", "rom", "nab", "lee"]
TEST_BENCHES = ["mcf", "xal", "wrf", "cac"]

_ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def rows() -> List[str]:
    return list(_ROWS)


def tao_config() -> TaoConfig:
    return TaoConfig(
        window=WINDOW,
        d_model=D_MODEL,
        n_heads=N_HEADS,
        n_layers=N_LAYERS,
        d_ff=D_FF,
        d_cat=D_CAT,
        features=FEATURES,
    )


# Benchmarks drive everything through the repro.api facade.  One Session
# per TaoConfig (the session caches captured traces and adjusted datasets).
_sessions: Dict[TaoConfig, Session] = {}


def session_for(cfg: TaoConfig) -> Session:
    s = _sessions.get(cfg)
    if s is None:
        s = Session(cfg)
        _sessions[cfg] = s
    return s


def session() -> Session:
    """The bench-scale default Session (config from ``tao_config()``)."""
    return session_for(tao_config())


def adjusted_dataset(uarch: MicroArchConfig, benches, n=None, features=FEATURES,
                     window=None) -> WindowDataset:
    """Trace -> §4.1 adjusted trace -> windows (Session-cached)."""
    n = n or TRACE_LEN
    cfg = tao_config()
    if features != cfg.features or (window is not None and window != cfg.window):
        cfg = dataclasses.replace(
            cfg, features=features, window=window or cfg.window
        )
    s = session_for(cfg)
    return s.dataset(uarch, [s.capture(b, n) for b in benches])


def ground_truth(uarch: MicroArchConfig, bench: str, n=None):
    s = session()
    tr = s.capture(bench, n or TEST_LEN)
    return tr.functional, s.ground_truth(uarch, tr)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
