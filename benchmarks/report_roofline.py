"""Render the §Roofline table from dry-run result JSONs.

Usage: PYTHONPATH=src python -m benchmarks.report_roofline \\
           [dryrun_results.json [dryrun_results_multi.json]]
"""
from __future__ import annotations

import json
import sys


def fmt(results: dict) -> str:
    hdr = (
        f"| {'arch':21s} | {'shape':11s} | {'dominant':10s} | {'comp ms':>8s} "
        f"| {'mem ms':>8s} | {'coll ms':>8s} | {'roofl%':>6s} | {'useful%':>7s} "
        f"| {'mem GiB':>8s} | fits |"
    )
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    lines = [hdr, sep]
    for key in sorted(results):
        v = results[key]
        if "error" in v:
            lines.append(f"| {key:46s} | ERROR: {v['error'][:60]} |")
            continue
        ro = v["roofline"]
        m = v["memory"]
        mem_gib = min(m["per_device_total"], m.get("tpu_estimate", m["per_device_total"])) / 2**30
        lines.append(
            f"| {v['arch']:21s} | {v['shape']:11s} | {ro['dominant'][:-2]:10s} "
            f"| {ro['compute_s']*1e3:8.2f} | {ro['memory_s']*1e3:8.2f} "
            f"| {ro['collective_s']*1e3:8.2f} | {ro['roofline_fraction']*100:6.1f} "
            f"| {ro['useful_flops_ratio']*100:7.1f} | {mem_gib:8.2f} "
            f"| {'Y' if m['fits_16gb'] else 'N'}    |"
        )
    return "\n".join(lines)


def main():
    paths = sys.argv[1:] or ["dryrun_results.json"]
    for p in paths:
        with open(p) as f:
            results = json.load(f)
        n_ok = sum(1 for v in results.values() if "error" not in v)
        print(f"\n== {p} ({n_ok}/{len(results)} cells ok) ==\n")
        print(fmt(results))


if __name__ == "__main__":
    main()
