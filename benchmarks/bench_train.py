"""Training-path streaming benchmarks (ROADMAP "Training-path streaming").

Measures the streaming training pipeline (`StreamingWindowDataset` +
process-wide cached train step) against the materialized `build_windows`
path on a synthetic trace:

  training/stream_windows_per_s        streaming data path + 1 train epoch
  training/materialized_windows_per_s  materialized path, same model/seed
  training/speedup                     stream / materialized
  training/peak_rss_stream_mb          peak RSS *delta* of the data path +
  training/peak_rss_materialized_mb      epoch, measured in a subprocess
                                         over a post-FeatureSet baseline
  training/rss_ratio                   materialized / stream (the ISSUE's
                                         >= 5x target at 1M instructions)
  training/train_compiles              train-step traces in the streaming
                                         subprocess (== 1 per geometry)
  training/loss_bitwise_equal          streaming loss trajectory is
                                         bit-identical to materialized
  training/dedup_hash_chunked          chunked window digesting vs the old
                                         per-row loop (same digests)

RSS runs happen in subprocesses (`python -m benchmarks.bench_train
--measure stream|materialized`) so each path's peak is attributed cleanly;
the subprocess pins ``JAX_PLATFORMS=cpu``.  CI uploads the rows as
``BENCH_train.json`` (suite name: ``training``).
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import resource
import subprocess
import sys
import time

import numpy as np

from repro.core import FeatureConfig, TaoConfig  # noqa: F401 (typing/docs)
from repro.core.dataset import (
    StreamingWindowDataset,
    build_windows,
    iter_window_digests,
    window_view,
)
from repro.core.features import NUM_OPCODES, FeatureSet
from repro.core.transfer import train_tao_impl
from repro.train.trainer import train_step_compiles
from repro.uarch.isa import NUM_REGS

from .common import FEATURES, SCALE, Timer, emit, tao_config

# instruction counts: EQ_N feeds the in-process bit-for-bit/compile checks,
# RSS_N the subprocess memory/throughput comparison (1M at full scale — the
# acceptance target)
EQ_N = {"tiny": 30_000, "small": 80_000, "full": 150_000}
RSS_N = {"tiny": 150_000, "small": 400_000, "full": 1_000_000}

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def synthetic_features(
    n: int,
    fcfg: FeatureConfig,
    *,
    seed: int = 0,
    window: int = 0,
    dup_every: int = 0,
) -> FeatureSet:
    """A random labeled FeatureSet of ``n`` instructions (no detailed sim —
    trace-scale inputs in milliseconds).  With ``dup_every`` > 0 every
    ``dup_every``-th window-aligned block repeats block 0, so the dedup
    paths have real collisions to resolve."""
    rng = np.random.default_rng(seed)
    # float32 draws throughout: float64 temporaries at 1M instructions would
    # dwarf the data-path allocations the RSS benchmark isolates
    fs = FeatureSet(
        opcode=rng.integers(0, NUM_OPCODES, n).astype(np.int32),
        regbits=(rng.random((n, NUM_REGS), dtype=np.float32) < 0.1).astype(np.float32),
        flags=(rng.random((n, 5), dtype=np.float32) < 0.3).astype(np.float32),
        brhist=rng.integers(-1, 2, (n, fcfg.n_queue)).astype(np.float32),
        memdist=rng.standard_normal((n, fcfg.n_mem), dtype=np.float32),
        labels={
            "fetch_lat": rng.integers(0, 8, n).astype(np.float32),
            "exec_lat": rng.integers(1, 12, n).astype(np.float32),
            "mispred": (rng.random(n) < 0.1).astype(np.float32),
            "dlevel": rng.integers(0, 4, n).astype(np.int32),
            "icache_miss": (rng.random(n) < 0.05).astype(np.float32),
            "tlb_miss": (rng.random(n) < 0.02).astype(np.float32),
            "is_branch": (rng.random(n) < 0.2).astype(np.float32),
            "is_mem": (rng.random(n) < 0.3).astype(np.float32),
        },
    )
    if dup_every and window:
        for k in range(dup_every, n // window, dup_every):
            lo = k * window
            for arr in (fs.opcode, fs.regbits, fs.flags, fs.brhist, fs.memdist,
                        *fs.labels.values()):
                arr[lo : lo + window] = arr[:window]
    return fs


def _rss_now_bytes() -> int:
    try:  # Linux: current resident set from /proc (page counts)
        with open("/proc/self/statm") as f:
            return int(f.read().split()[1]) * os.sysconf("SC_PAGESIZE")
    except (OSError, ValueError, IndexError):
        kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        return kb * (1 if sys.platform == "darwin" else 1024)


class _RssPeak:
    """Peak resident-set size over a region, via a 1 ms sampling thread.

    ``ru_maxrss`` is process-lifetime-monotonic: allocation spikes during
    setup (feature generation, XLA compilation) would mask the data path's
    own peak.  Sampling the *current* RSS bounds the measurement to the
    region of interest."""

    def __enter__(self):
        import threading

        self.peak = _rss_now_bytes()
        self._stop = threading.Event()

        def sample():
            while not self._stop.is_set():
                self.peak = max(self.peak, _rss_now_bytes())
                self._stop.wait(0.001)

        self._t = threading.Thread(target=sample, daemon=True)
        self._t.start()
        return self

    def __exit__(self, *a):
        self._stop.set()
        self._t.join()
        self.peak = max(self.peak, _rss_now_bytes())


def _measure(mode: str, n: int) -> dict:
    """Subprocess body: peak-RSS delta + throughput of one data path.

    The FeatureSet (O(trace), common to both paths) and the train-step
    compile are built BEFORE the RSS baseline, so the delta isolates what
    this PR changes: windowing, dedup, shuffling, and batch materialization
    (plus the per-batch jax buffers, identical in both modes)."""
    cfg = tao_config()
    fs = synthetic_features(n, FEATURES, seed=1, window=cfg.window, dup_every=7)
    warm = StreamingWindowDataset(fs.slice(0, cfg.window * 64), cfg.window)
    train_tao_impl(cfg, warm, epochs=1, batch_size=16, seed=0)
    import gc

    gc.collect()
    base = _rss_now_bytes()

    with _RssPeak() as rss:
        t0 = time.perf_counter()
        if mode == "stream":
            ds = StreamingWindowDataset(fs, cfg.window)
        else:
            ds = build_windows(fs, cfg.window)
        build_secs = time.perf_counter() - t0
        c0 = train_step_compiles()
        t1 = time.perf_counter()
        res = train_tao_impl(cfg, ds, epochs=1, batch_size=16, seed=0)
        train_secs = time.perf_counter() - t1
    return {
        "mode": mode,
        "n": n,
        "windows": len(ds),
        "peak_rss_delta_mb": (rss.peak - base) / 1e6,
        "build_seconds": build_secs,
        "train_seconds": train_secs,
        "windows_per_s": res.steps * 16 / (build_secs + train_secs),
        "compiles_during_train": train_step_compiles() - c0,
        "train_compiles_total": train_step_compiles(),
        "loss0": res.losses[0],
    }


def _spawn_measure(mode: str, n: int) -> dict:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"  # subprocess must never probe TPU
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_train",
         "--measure", mode, "--n", str(n)],
        capture_output=True, text=True, timeout=3600, env=env, cwd=_ROOT,
    )
    if p.returncode != 0:
        raise RuntimeError(f"measure {mode} failed:\n{p.stderr[-3000:]}")
    return json.loads(p.stdout.strip().splitlines()[-1])


def _per_row_digests(inputs, labels):
    """The pre-vectorization per-row hashing loop (kept here as the
    baseline the chunked implementation is benchmarked against)."""
    out = []
    lat = labels["fetch_lat"] if labels is not None else None
    for i in range(len(inputs["opcode"])):
        h = hashlib.blake2b(digest_size=16)
        h.update(inputs["opcode"][i].tobytes())
        h.update(inputs["memdist"][i].tobytes())
        h.update(inputs["brhist"][i].tobytes())
        if lat is not None:
            h.update(lat[i].tobytes())
            h.update(labels["exec_lat"][i].tobytes())
        out.append(h.digest())
    return out


def run() -> None:
    cfg = tao_config()
    n = EQ_N[SCALE]
    fs = synthetic_features(n, FEATURES, seed=0, window=cfg.window, dup_every=5)

    # --- bit-for-bit: streaming vs materialized loss trajectory ---------
    ds_s = StreamingWindowDataset(fs, cfg.window)
    ds_m = build_windows(fs, cfg.window)
    c0 = train_step_compiles()
    res_s = train_tao_impl(cfg, ds_s, epochs=2, batch_size=16, seed=0)
    compiles = train_step_compiles() - c0
    res_m = train_tao_impl(cfg, ds_m, epochs=2, batch_size=16, seed=0)
    equal = int(res_s.losses == res_m.losses and len(ds_s) == len(ds_m))
    emit(
        "training/loss_bitwise_equal",
        0.0,
        f"equal={equal} windows={len(ds_s)} dropped={ds_s.num_dropped}",
    )
    emit(
        "training/train_compiles",
        0.0,
        f"compiles={compiles} (streaming epochs=2; 1 per geometry)",
    )

    # --- chunked vs per-row window hashing (same digests) ---------------
    dense = {  # stride-1 views: one window per trace position, zero copies
        k: window_view(getattr(fs, k), cfg.window, 1)
        for k in ("opcode", "memdist", "brhist")
    }
    labs = {  # training dedup hashes labels too — the realistic case
        k: window_view(fs.labels[k], cfg.window, 1)
        for k in ("fetch_lat", "exec_lat")
    }
    with Timer() as t_chunk:
        chunked = list(iter_window_digests(dense, labs))
    with Timer() as t_row:
        per_row = _per_row_digests(dense, labs)
    assert chunked == per_row
    emit(
        "training/dedup_hash_chunked",
        t_chunk.seconds * 1e6 / len(chunked),
        f"windows={len(chunked)} speedup={t_row.seconds / t_chunk.seconds:.2f}x"
        " (blake2b compression is the remaining floor)",
    )

    # --- subprocess peak-RSS + throughput comparison --------------------
    rss_n = RSS_N[SCALE]
    stream = _spawn_measure("stream", rss_n)
    mat = _spawn_measure("materialized", rss_n)
    assert stream["loss0"] == mat["loss0"]  # same keep-set, same first epoch
    emit(
        "training/stream_windows_per_s",
        1e6 / max(stream["windows_per_s"], 1e-9),
        f"windows_per_s={stream['windows_per_s']:.0f} n={rss_n}",
    )
    emit(
        "training/materialized_windows_per_s",
        1e6 / max(mat["windows_per_s"], 1e-9),
        f"windows_per_s={mat['windows_per_s']:.0f} n={rss_n}",
    )
    emit(
        "training/speedup",
        0.0,
        f"stream_vs_materialized={stream['windows_per_s'] / mat['windows_per_s']:.2f}x",
    )
    emit(
        "training/peak_rss_stream_mb",
        0.0,
        f"mb={stream['peak_rss_delta_mb']:.1f} n={rss_n} "
        f"compiles_during_train={stream['compiles_during_train']} "
        f"total={stream['train_compiles_total']}",
    )
    emit(
        "training/peak_rss_materialized_mb",
        0.0,
        f"mb={mat['peak_rss_delta_mb']:.1f} n={rss_n}",
    )
    ratio = mat["peak_rss_delta_mb"] / max(stream["peak_rss_delta_mb"], 1e-9)
    emit("training/rss_ratio", 0.0, f"materialized_vs_stream={ratio:.1f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--measure", choices=("stream", "materialized"))
    ap.add_argument("--n", type=int, default=None)
    args = ap.parse_args()
    if args.measure:
        print(json.dumps(_measure(args.measure, args.n or RSS_N[SCALE])))
    else:
        run()
