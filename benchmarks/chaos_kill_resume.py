"""Kill-and-resume smoke: SIGKILL a resumable sweep, resume, compare.

The real-process version of the chaos suite's in-process crash test:

1. a reference child runs a 4-job DSE-style sweep uninterrupted;
2. a victim child runs the same sweep with ``resume_key`` against an
   ``ArtifactStore``, with a ``REPRO_FAULT_PLAN`` delay fault parking it
   mid-job after 2 progress manifests have landed — the parent SIGKILLs
   it there (a genuinely torn process, not a polite exception);
3. a resume child re-runs the identical invocation and must skip the 2
   completed jobs, extract 0 features (the remainder's features come
   from the store), and produce metrics bit-identical to the reference.

CI's chaos-smoke job runs ``python -m benchmarks.chaos_kill_resume``.
Exit code 0 = all assertions held.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")

_RESUME_KEY = "chaos-kill-resume"
_N_DONE_BEFORE_KILL = 2   # manifests published before the victim parks
_PARK_S = 600.0           # far longer than the parent's kill latency
_VICTIM_PLAN = json.dumps({
    "faults": [{
        "site": "scheduler.consume",
        "kind": "delay",
        "after": _N_DONE_BEFORE_KILL,
        "delay_s": _PARK_S,
    }],
})


# ---------------------------------------------------------------------------
# child: one sweep run, result on stdout


def _child(store_root: str, resume_key: str) -> None:
    import jax
    import numpy as np

    from repro.api import ArtifactStore
    from repro.core import init_tao
    from repro.engine import EngineConfig
    from repro.engine.scheduler import SweepJob, TraceSweeper
    from repro.resilience import FaultPlan, inject

    from benchmarks.common import TEST_LEN, session, tao_config

    cfg = tao_config()
    s = session()
    t1 = s.capture("mcf", TEST_LEN).functional
    t2 = s.capture("dee", max(cfg.window * 3, TEST_LEN // 2)).functional
    p1 = init_tao(jax.random.PRNGKey(0), cfg)
    p2 = init_tao(jax.random.PRNGKey(1), cfg)
    jobs = [
        SweepJob("m1/a", p1, t1), SweepJob("m1/b", p1, t2),
        SweepJob("m2/a", p2, t1), SweepJob("m2/b", p2, t2),
    ]
    store = ArtifactStore(store_root) if store_root else None
    # arm the CI chaos knob if set (inject(None) is a pass-through) —
    # the victim run parks on a delay fault here until SIGKILLed
    with inject(FaultPlan.from_env()):
        report = TraceSweeper(cfg, EngineConfig(batch_size=8),
                              store=store).run(
            jobs, resume_key=resume_key or None)
    out = {
        "jobs_skipped": report.jobs_skipped,
        "features_extracted": report.features_extracted,
        "features_from_store": report.features_from_store,
        "num_traces": report.num_traces,
        "metrics": {
            key: {m: np.asarray(v).tolist() for m, v in r.metrics.items()}
            for key, r in report.results.items()
        },
    }
    print("RESULT " + json.dumps(out), flush=True)


# ---------------------------------------------------------------------------
# parent: orchestrate ref / victim / resume


def _spawn(store_root: str, resume_key: str, extra_env=None):
    env = os.environ.copy()
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, [_SRC, env.get("PYTHONPATH")]))
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.pop("REPRO_FAULT_PLAN", None)
    env.update(extra_env or {})
    return subprocess.Popen(
        [sys.executable, "-m", "benchmarks.chaos_kill_resume",
         "--child", "--store", store_root, "--resume-key", resume_key],
        cwd=_ROOT, env=env,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
    )


def _result(proc, label: str, timeout_s: float = 600.0) -> dict:
    out, _ = proc.communicate(timeout=timeout_s)
    if proc.returncode != 0:
        sys.stderr.write(out)
        raise SystemExit(f"{label} child failed rc={proc.returncode}")
    for line in out.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    sys.stderr.write(out)
    raise SystemExit(f"{label} child printed no RESULT line")


def _progress_count(store_root: str) -> int:
    kdir = os.path.join(store_root, "objects", "sweep_progress")
    if not os.path.isdir(kdir):
        return 0
    return sum(
        len(os.listdir(os.path.join(kdir, prefix)))
        for prefix in os.listdir(kdir)
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--store", default="")
    ap.add_argument("--resume-key", default="")
    args = ap.parse_args()
    if args.child:
        _child(args.store, args.resume_key)
        return

    with tempfile.TemporaryDirectory(prefix="chaos-resume-") as tmp:
        store = os.path.join(tmp, "store")

        print("chaos_kill_resume: reference run ...", flush=True)
        ref = _result(_spawn("", ""), "reference")
        assert ref["num_traces"] == 4, ref

        print("chaos_kill_resume: victim run (will be SIGKILLed) ...",
              flush=True)
        victim = _spawn(store, _RESUME_KEY,
                        extra_env={"REPRO_FAULT_PLAN": _VICTIM_PLAN})
        deadline = time.monotonic() + 300.0
        while _progress_count(store) < _N_DONE_BEFORE_KILL:
            if victim.poll() is not None:
                out, _ = victim.communicate()
                sys.stderr.write(out)
                raise SystemExit(
                    "victim exited before publishing enough progress "
                    f"(rc={victim.returncode})")
            if time.monotonic() > deadline:
                victim.kill()
                raise SystemExit("timed out waiting for victim progress")
            time.sleep(0.05)
        os.kill(victim.pid, signal.SIGKILL)
        victim.communicate()
        print(f"chaos_kill_resume: killed victim pid={victim.pid} with "
              f"{_progress_count(store)} manifests published", flush=True)

        print("chaos_kill_resume: resume run ...", flush=True)
        res = _result(_spawn(store, _RESUME_KEY), "resume")

        assert res["jobs_skipped"] == _N_DONE_BEFORE_KILL, res
        assert res["features_extracted"] == 0, res
        assert res["num_traces"] == 4, res
        assert set(res["metrics"]) == set(ref["metrics"]), (
            sorted(res["metrics"]), sorted(ref["metrics"]))
        for key in ref["metrics"]:
            assert res["metrics"][key] == ref["metrics"][key], (
                f"metrics diverge for {key}")
        print("chaos_kill_resume: OK — resume skipped "
              f"{res['jobs_skipped']} jobs, extracted 0 features, "
              "metrics bit-identical to the uninterrupted run", flush=True)


if __name__ == "__main__":
    main()
