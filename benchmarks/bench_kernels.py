"""Kernel micro-benchmarks: the model-side flash attention (chunked jnp,
what the CPU path runs and the TPU kernel mirrors) vs the naive reference,
and the SSD chunked scan vs the sequential recurrence.

On CPU the interesting number is the XLA-compiled wall time of the chunked
formulations (the Pallas kernels themselves are only validated in interpret
mode — their perf target is the TPU; see EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ssd.ref import ssd_sequential_ref
from repro.models.attention import flash_ref
from repro.models.mamba2 import ssd_chunked_ref

from .common import emit


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run() -> None:
    key = jax.random.PRNGKey(0)
    # attention: naive materializes S^2, flash stays blocked
    for S in (512, 2048):
        B, H, D = 1, 4, 64
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, H, S, D))
        k = jax.random.normal(ks[1], (B, H, S, D))
        v = jax.random.normal(ks[2], (B, H, S, D))

        naive = jax.jit(
            lambda q, k, v: jax.nn.softmax(
                jnp.where(
                    jnp.tril(jnp.ones((S, S), bool))[None, None],
                    jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(D),
                    -jnp.inf,
                ),
                -1,
            )
            @ v
        )
        flash = jax.jit(lambda q, k, v: flash_ref(q, k, v, causal=True))
        t_naive = _time(naive, q, k, v)
        t_flash = _time(flash, q, k, v)
        emit(
            f"kernels/attn_S{S}",
            t_flash * 1e6,
            f"naive_us={t_naive*1e6:.0f};flash_us={t_flash*1e6:.0f}",
        )

    # SSD: chunked (parallel) vs sequential recurrence
    B, S, H, P, G, N = 1, 2048, 8, 32, 1, 32
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    seq = jax.jit(ssd_sequential_ref)
    chk = jax.jit(lambda *a: ssd_chunked_ref(*a, chunk=128))
    t_seq = _time(seq, xh, dt, A, Bm, Cm)
    t_chk = _time(chk, xh, dt, A, Bm, Cm)
    emit(
        f"kernels/ssd_S{S}",
        t_chk * 1e6,
        f"sequential_us={t_seq*1e6:.0f};chunked_us={t_chk*1e6:.0f};"
        f"speedup={t_seq/t_chk:.1f}x",
    )
