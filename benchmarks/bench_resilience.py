"""Resilience benchmark: latency under injected faults + recovery time.

Quantifies what the chaos suite only asserts: with a seeded
:class:`~repro.resilience.FaultPlan` dropping a fixed fraction of
dispatches, how much does tail latency degrade (retries are paid inline
by the affected requests), and once a circuit breaker has tripped on a
hard-failing ``model/geometry``, how long until the server is serving
that bucket again?  CI tracks both per PR via ``BENCH_resilience.json``:
a regression in ``resilience/degraded_p99`` means retry backoff got more
expensive; a regression in ``resilience/recovery`` means the breaker
probe path got slower.

Three phases over the same closed-loop workload:

1. **clean** — no faults, baseline p50/p99.
2. **degraded** — ``serve.dispatch`` fails with seeded probability;
   the retry policy re-runs victims, so the load still completes.
3. **recovery** — a burst of hard failures trips the per-geometry
   breaker; we then measure wall time from the trip until a request for
   that geometry completes again (cooldown + half-open probe).
"""
from __future__ import annotations

import asyncio
import time

import jax
import numpy as np

from repro.api import (
    ModelRegistry,
    ServeError,
    ServeRequest,
    TraceServer,
    TrainedModel,
)
from repro.core import init_tao
from repro.resilience import FaultPlan, FaultSpec, RetryPolicy, inject

from .common import SCALE, TEST_LEN, Timer, emit, session, set_extra, tao_config

_N_REQUESTS = {"tiny": 16, "small": 48}.get(SCALE, 96)
# seeded so the degraded phase replays the identical fault sequence
# run-to-run: the p99 delta is attributable to code, not dice
_FAULT_P = 0.2
_FAULT_SEED = 17
_RETRY = RetryPolicy(max_attempts=3, base_delay_s=0.002, multiplier=2.0)
_BREAKER_THRESHOLD = 3
_COOLDOWN_S = 0.1


def _build():
    cfg = tao_config()
    s = session()
    traces = [
        s.capture("mcf", TEST_LEN),
        s.capture("dee", max(cfg.window * 3, TEST_LEN // 2)),
    ]
    registry = ModelRegistry()
    for i, name in enumerate(("base", "tuned")):
        registry.register(name, TrainedModel(
            params=init_tao(jax.random.PRNGKey(i), cfg), cfg=cfg, name=name))
    return registry, traces


async def _closed_loop(server, traces, n):
    """Sequential closed loop; returns (latencies, failures).  Failed
    requests (retry budget exhausted under the plan) are counted, not
    fatal — availability under faults is part of the measurement."""
    lat, failures = [], 0
    for i in range(n):
        req = ServeRequest(
            model=("base", "tuned")[i % 2],
            trace=traces[i % len(traces)],
            tenant=f"t{i % 4}",
        )
        try:
            r = await server.submit(req)
            lat.append(r.total_s)
        except ServeError as e:
            failures += 1
            if e.code == "CIRCUIT_OPEN":
                await asyncio.sleep(e.retry_after_s or _COOLDOWN_S)
    return np.array(lat), failures


async def _measure_recovery(server, traces):
    """Trip the breaker for base/traces[0]'s geometry with hard transient
    faults, then poll until a request for that bucket completes again."""
    # every attempt fails: max_attempts fires per request, so
    # _BREAKER_THRESHOLD failed requests open the circuit
    trip_plan = FaultPlan(
        FaultSpec("serve.dispatch",
                  times=_RETRY.max_attempts * _BREAKER_THRESHOLD,
                  transient=True, message="bench breaker trip"),
        seed=_FAULT_SEED,
    )
    req = ServeRequest(model="base", trace=traces[0])
    with inject(trip_plan):
        for _ in range(_BREAKER_THRESHOLD):
            try:
                await server.submit(req)
            except ServeError:
                pass  # INTERNAL while tripping — expected
    t_open = time.perf_counter()
    sheds = 0
    while True:
        try:
            await server.submit(req)
            return time.perf_counter() - t_open, sheds
        except ServeError as e:
            if e.code != "CIRCUIT_OPEN":
                raise
            sheds += 1
            await asyncio.sleep(e.retry_after_s or _COOLDOWN_S / 4)


def run() -> None:
    registry, traces = _build()

    async def drive():
        server = TraceServer(
            registry, batch_size=8, max_queue=128,
            retry=_RETRY,
            breaker_threshold=_BREAKER_THRESHOLD,
            breaker_cooldown_s=_COOLDOWN_S,
        )
        async with server:
            server.warmup([len(t) for t in traces])
            # prime feature caches for every model x trace pair so the
            # clean phase measures steady state, not first-touch extraction
            await _closed_loop(server, traces, 2 * len(traces))
            clean, clean_failures = await _closed_loop(
                server, traces, _N_REQUESTS)

            plan = FaultPlan(
                FaultSpec("serve.dispatch", p=_FAULT_P, times=None,
                          transient=True, message="bench degraded mode"),
                seed=_FAULT_SEED,
            )
            with inject(plan):
                with Timer() as degraded_wall:
                    degraded, degraded_failures = await _closed_loop(
                        server, traces, _N_REQUESTS)
            mid_stats = server.stats()

            recovery_s, recovery_sheds = await _measure_recovery(
                server, traces)
            stats = server.stats()
        return (clean, clean_failures, degraded, degraded_failures,
                degraded_wall.seconds, mid_stats, recovery_s,
                recovery_sheds, stats)

    (clean, clean_failures, degraded, degraded_failures, degraded_wall,
     mid_stats, recovery_s, recovery_sheds, stats) = asyncio.run(drive())

    assert clean_failures == 0, "clean phase must not fail"
    p50_c, p99_c = np.percentile(clean, 50), np.percentile(clean, 99)
    p50_d, p99_d = np.percentile(degraded, 50), np.percentile(degraded, 99)

    emit("resilience/clean_p99", p99_c * 1e6, f"n={len(clean)}")
    emit("resilience/degraded_p99", p99_d * 1e6,
         f"retries={mid_stats.retries} failed={degraded_failures} "
         f"x{p99_d / max(p99_c, 1e-9):.2f}")
    emit("resilience/recovery", recovery_s * 1e6,
         f"sheds={recovery_sheds} "
         f"breaker_sheds={stats.breaker_sheds}")
    set_extra("resilience", {
        "latency_p50_clean_s": float(p50_c),
        "latency_p99_clean_s": float(p99_c),
        "latency_p50_degraded_s": float(p50_d),
        "latency_p99_degraded_s": float(p99_d),
        "degraded_wall_s": float(degraded_wall),
        "degraded_failures": degraded_failures,
        "degraded_retries": mid_stats.retries,
        "fault_p": _FAULT_P,
        "recovery_s": float(recovery_s),
        "recovery_sheds": recovery_sheds,
        "stats": stats.to_dict(),
    })
