"""Benchmark harness — one module per paper table/figure.

  bench_accuracy   Fig 9     accuracy vs SimNet baseline
  bench_timing     Table 4 + Fig 10   trace economics / end-to-end time
  bench_sweeps     Fig 12    feature-parameter sweeps (N_m, N_b, N_q)
  bench_transfer   Fig 13/14 + Table 5/6  agnostic embeddings + transfer
  bench_dse        Fig 15    design-space exploration
                   + "sweep": async Session.sweep scheduler stats
                     (traces/s, compiles, queue occupancy)
                   + "coldstart": first-result latency cold vs warm
                     persistent caches (artifact store + XLA executables)
  bench_train      (systems) streaming vs materialized training pipeline
                     (windows/s, peak RSS, compile counts)
  bench_kernels    (systems) chunked attention / SSD formulations
  bench_serve      (systems) "serve": open-loop multi-tenant TraceServer
                     load (p50/p99 latency, traces/s, batch fill ratio)
  bench_resilience (systems) "resilience": degraded-mode tail latency
                     under a seeded fault plan + breaker recovery time
                     (CI uploads ``BENCH_resilience.json``)

Prints ``name,us_per_call,derived`` CSV.  BENCH_SCALE=tiny|small|full
controls trace lengths / epochs (CPU container defaults to small; CI smoke
uses tiny).  Run a subset: ``python -m benchmarks.run --only fig9,table4``.
``--json PATH`` additionally writes the rows as structured JSON (the CI
bench-smoke job uploads ``BENCH_timing.json``, ``BENCH_dse.json``, and ``BENCH_train.json`` as
artifacts so the perf trajectory — including the async sweep scheduler's
numbers — is tracked per PR).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

from . import (
    bench_accuracy,
    bench_dse,
    bench_kernels,
    bench_resilience,
    bench_serve,
    bench_shard,
    bench_sweeps,
    bench_timing,
    bench_train,
    bench_transfer,
)
from .common import SCALE, emit, extras, rows

SUITES = {
    "fig9": bench_accuracy.run,
    "table4": bench_timing.run,
    "fig12": bench_sweeps.run,
    "fig13_14_t5": bench_transfer.run,
    "fig15": bench_dse.run,
    "sweep": bench_dse.run_sweep,
    "coldstart": bench_dse.run_coldstart,
    "training": bench_train.run,
    "kernels": bench_kernels.run,
    "shard": bench_shard.run,
    "serve": bench_serve.run,
    "resilience": bench_resilience.run,
}


def _write_json(path: str) -> None:
    # device/mesh topology + persistent-cache status ride along so
    # artifacts from different hosts (CI runners, TPU pods, laptops) are
    # comparable at a glance — and so a bench run against a warm compile
    # cache is distinguishable from a truly cold one
    from repro.distributed import topology_info
    from repro.engine import persistent_cache_status

    records = []
    for row in rows():
        name, us, derived = row.split(",", 2)
        records.append(
            {"name": name, "us_per_call": float(us), "derived": derived}
        )
    payload = {
        "scale": SCALE,
        "topology": topology_info(),
        "persistent_cache": persistent_cache_status(),
        "rows": records,
        **extras(),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path} ({len(records)} rows)", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    ap.add_argument("--json", default=None, help="also write rows to this JSON file")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")

    # $REPRO_COMPILE_CACHE persists compiled executables across bench runs
    # (CI restores it via actions/cache): first-run compile time disappears
    # from later runs without touching any measured steady-state number —
    # every suite warms up before its timed section.
    if os.environ.get("REPRO_COMPILE_CACHE"):
        from repro.engine import enable_persistent_cache

        enable_persistent_cache()

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    for name in names:
        try:
            t = time.time()
            SUITES[name]()
            emit(f"{name}/total", (time.time() - t) * 1e6, "ok")
        except Exception as e:  # record and continue
            failures += 1
            emit(f"{name}/total", 0.0, f"FAILED:{type(e).__name__}:{e}")
            traceback.print_exc()
    emit("all/total", (time.time() - t0) * 1e6, f"failures={failures}")
    if args.json:
        _write_json(args.json)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
