"""Fig. 12 — input-feature parameter sweeps: memory-context queue size N_m
and branch-history table (N_b, N_q)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import FeatureConfig
from repro.uarch import UARCH_A

from .common import (
    EPOCHS,
    TEST_BENCHES,
    TRAIN_BENCHES,
    adjusted_dataset,
    emit,
    ground_truth,
    session_for,
    tao_config,
)


def _error_with_features(fcfg: FeatureConfig) -> float:
    cfg = dataclasses.replace(tao_config(), features=fcfg)
    ds = adjusted_dataset(UARCH_A, TRAIN_BENCHES[:2], features=fcfg)
    model = session_for(cfg).train(
        dataset=ds, epochs=max(3, EPOCHS // 2), batch_size=16, lr=1e-3
    )
    errs = []
    for bench in TEST_BENCHES[:2]:
        ft, truth = ground_truth(UARCH_A, bench)
        sim = model.simulate(ft)
        errs.append(sim.error_vs(truth["cpi"]))
    return float(np.mean(errs))


def run() -> None:
    # Fig 12a: N_m sweep (paper: improves to N_m=64, marginal beyond)
    for n_mem in (4, 16, 32):
        err = _error_with_features(FeatureConfig(n_buckets=256, n_queue=8, n_mem=n_mem))
        emit(f"fig12a/n_mem={n_mem}", 0.0, f"avg_cpi_err={err:.2f}%")
    # Fig 12b: (N_b, N_q) sweep
    for nb, nq in ((64, 4), (256, 8), (512, 16)):
        err = _error_with_features(FeatureConfig(n_buckets=nb, n_queue=nq, n_mem=16))
        emit(f"fig12b/nb={nb},nq={nq}", 0.0, f"avg_cpi_err={err:.2f}%")
