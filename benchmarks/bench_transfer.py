"""Fig. 13 + Fig. 14 + Table 5 + Table 6 — microarchitecture-agnostic
embeddings: multi-arch training-method comparison, training-pair selection,
and transfer-learning cost.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    init_multiarch,
    make_joint_step,
    measure_design_metrics,
    select_pair_euclidean,
    select_pair_mahalanobis,
    select_random,
    simulate_trace,
    train_tao,
    transfer_finetune,
)
from repro.core.multiarch import eval_loss
from repro.train.optim import AdamWConfig, adamw_init
from repro.uarch import UARCH_A, UARCH_B, UARCH_C, sample_design_space

from .common import (
    EPOCHS,
    TEST_BENCHES,
    TRAIN_BENCHES,
    Timer,
    adjusted_dataset,
    emit,
    ground_truth,
    tao_config,
)


def _joint_batches(uarch, rng, batch_size=16):
    ds = adjusted_dataset(uarch, TRAIN_BENCHES[:2])
    for b in ds.batches(batch_size, rng=rng):
        b["labels"] = {k: jnp.asarray(v) for k, v in b.pop("labels").items()}
        yield b


def _eval_batches(uarch, n=6):
    ds = adjusted_dataset(uarch, [TEST_BENCHES[0]])
    out = []
    for i, b in enumerate(ds.batches(16)):
        if i >= n:
            break
        b["labels"] = {k: jnp.asarray(v) for k, v in b.pop("labels").items()}
        out.append(b)
    return out


def run_fig13() -> None:
    """Convergence of the shared-embedding training methods (paper ordering:
    Tao < GradNorm < Granite test error; Tao-w/o-adapt between)."""
    cfg = tao_config()
    eval_a = _eval_batches(UARCH_A)
    eval_b = _eval_batches(UARCH_B)
    finals = {}
    for method in ("granite", "gradnorm", "tao_no_adapt", "tao"):
        params = init_multiarch(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        step = make_joint_step(cfg, AdamWConfig(lr=1e-3), method=method)
        w = jnp.ones((2,))
        il = None
        rng = np.random.default_rng(0)
        with Timer() as t:
            for epoch in range(EPOCHS):
                for ba, bb in zip(
                    _joint_batches(UARCH_A, rng), _joint_batches(UARCH_B, rng)
                ):
                    params, opt, w, m = step(
                        params, opt, w,
                        il if il is not None else jnp.ones((2,)), ba, bb,
                    )
                    if il is None:
                        il = jnp.asarray([float(m["loss_a"]), float(m["loss_b"])])
        use_adapt = method in ("tao",)
        te = 0.5 * (
            eval_loss(params, eval_a, cfg, "A", use_adapt=use_adapt)
            + eval_loss(params, eval_b, cfg, "B", use_adapt=use_adapt)
        )
        finals[method] = te
        emit(f"fig13/{method}", t.seconds * 1e6 / max(1, EPOCHS), f"test_loss={te:.4f}")
    order = sorted(finals, key=finals.get)
    emit("fig13/ordering", 0.0, "best_to_worst=" + ">".join(order))


def run_fig14() -> None:
    """Training-pair selection: Mahalanobis vs Euclidean vs random over a
    sampled design space (paper: MD best, ~6.3% vs 7.5% vs 8.5%)."""
    designs = sample_design_space(8, seed=42)
    metrics = measure_design_metrics(designs, TRAIN_BENCHES[:2], instructions=3000)
    mi, mj = select_pair_mahalanobis(metrics)
    ei, ej = select_pair_euclidean(metrics)
    ri, rj = select_random(len(designs), 2, seed=7)

    cfg = tao_config()

    def embed_error(i, j) -> float:
        params = init_multiarch(jax.random.PRNGKey(1), cfg)
        opt = adamw_init(params)
        step = make_joint_step(cfg, AdamWConfig(lr=1e-3), method="tao")
        w = jnp.ones((2,))
        rng = np.random.default_rng(1)
        dsa = adjusted_dataset(designs[i], TRAIN_BENCHES[:2])
        dsb = adjusted_dataset(designs[j], TRAIN_BENCHES[:2])
        for epoch in range(max(3, EPOCHS // 2)):
            for ba, bb in zip(dsa.batches(16, rng=rng), dsb.batches(16, rng=rng)):
                ba["labels"] = {k: jnp.asarray(v) for k, v in ba.pop("labels").items()}
                bb["labels"] = {k: jnp.asarray(v) for k, v in bb.pop("labels").items()}
                params, opt, w, m = step(params, opt, w, jnp.ones((2,)), ba, bb)
        # transfer to unseen µArch C with frozen embeddings, measure CPI error
        ds_c = adjusted_dataset(UARCH_C, TRAIN_BENCHES[:1])
        res = transfer_finetune(cfg, params["embed"], params["A"], ds_c,
                                epochs=max(2, EPOCHS // 3), batch_size=16, lr=1e-3)
        errs = []
        for bench in TEST_BENCHES[:2]:
            ft, truth = ground_truth(UARCH_C, bench)
            sim = simulate_trace(res.params, ft, cfg)
            errs.append(sim.error_vs(truth["cpi"]))
        return float(np.mean(errs))

    for name, (i, j) in (
        ("mahalanobis", (mi, mj)),
        ("euclidean", (ei, ej)),
        ("random", (ri, rj)),
    ):
        err = embed_error(i, j)
        emit(f"fig14/{name}", 0.0, f"pair=({i},{j});transfer_cpi_err={err:.2f}%")


def run_table5() -> None:
    """Transfer-learning training cost to a fixed loss target."""
    cfg = tao_config()
    ds_c = adjusted_dataset(UARCH_C, TRAIN_BENCHES[:2])
    small_c = ds_c.subsample(max(16, len(ds_c) // 5))

    # donor + shared embeddings from A/B joint training (reuse quick run)
    params = init_multiarch(jax.random.PRNGKey(2), cfg)
    opt = adamw_init(params)
    step = make_joint_step(cfg, AdamWConfig(lr=1e-3), method="tao")
    w = jnp.ones((2,))
    rng = np.random.default_rng(2)
    for epoch in range(max(3, EPOCHS // 2)):
        for ba, bb in zip(_joint_batches(UARCH_A, rng), _joint_batches(UARCH_B, rng)):
            params, opt, w, _ = step(params, opt, w, jnp.ones((2,)), ba, bb)

    # measure target loss = scratch's achievable loss, then time each regime
    with Timer() as t_scratch:
        r_scratch = train_tao(cfg, ds_c, epochs=EPOCHS, batch_size=16, lr=1e-3)
    target = r_scratch.losses[-1] * 1.1

    with Timer() as t_direct:
        r_direct = train_tao(
            cfg, ds_c, epochs=EPOCHS, batch_size=16, lr=1e-3,
            init_params=r_scratch.params, target_loss=target,
        )
    with Timer() as t_shared:
        r_shared = transfer_finetune(
            cfg, params["embed"], params["A"], small_c,
            epochs=EPOCHS, batch_size=16, lr=1e-3, target_loss=target,
        )
    emit(
        "table5/training_time",
        t_shared.seconds * 1e6,
        f"scratch_s={t_scratch.seconds:.1f};direct_ft_s={t_direct.seconds:.1f};"
        f"shared+ft_s={t_shared.seconds:.1f};"
        f"speedup={t_scratch.seconds/max(t_shared.seconds,1e-9):.1f}x(paper:29.5x);"
        f"losses={r_scratch.losses[-1]:.3f}/{r_direct.losses[-1]:.3f}/{r_shared.losses[-1]:.3f}",
    )


def run_table6() -> None:
    """One-time embedding-construction overhead decomposition."""
    with Timer() as t_sim:
        designs = sample_design_space(8, seed=11)
        metrics = measure_design_metrics(designs, TRAIN_BENCHES[:1], instructions=2000)
    with Timer() as t_sel:
        select_pair_mahalanobis(metrics)
    emit(
        "table6/overhead",
        t_sel.seconds * 1e6,
        f"design_sim_s={t_sim.seconds:.2f};selection_s={t_sel.seconds:.4f}"
        f"(paper:0.35h sim,0.1min select)",
    )


def run() -> None:
    run_fig13()
    run_fig14()
    run_table5()
    run_table6()
