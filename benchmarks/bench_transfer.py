"""Fig. 13 + Fig. 14 + Table 5 + Table 6 — microarchitecture-agnostic
embeddings: multi-arch training-method comparison, training-pair selection,
and transfer-learning cost.  Driven through the ``repro.api`` facade
(``Session.train_joint`` / ``JointModel.transfer`` / ``Session.train``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.api import DesignSpace
from repro.uarch import UARCH_A, UARCH_B, UARCH_C

from .common import (
    EPOCHS,
    TEST_BENCHES,
    TRAIN_BENCHES,
    Timer,
    adjusted_dataset,
    emit,
    ground_truth,
    session,
)


def _eval_batches(uarch, n=6):
    ds = adjusted_dataset(uarch, [TEST_BENCHES[0]])
    out = []
    for i, b in enumerate(ds.batches(16)):
        if i >= n:
            break
        b["labels"] = {k: jnp.asarray(v) for k, v in b.pop("labels").items()}
        out.append(b)
    return out


def _joint(method: str, ua, ub, *, epochs, seed=0):
    sess = session()
    return sess.train_joint(
        ua, ub,
        datasets=(
            adjusted_dataset(ua, TRAIN_BENCHES[:2]),
            adjusted_dataset(ub, TRAIN_BENCHES[:2]),
        ),
        method=method, epochs=epochs, batch_size=16, lr=1e-3, seed=seed,
    )


def run_fig13() -> None:
    """Convergence of the shared-embedding training methods (paper ordering:
    Tao < GradNorm < Granite test error; Tao-w/o-adapt between)."""
    eval_a = _eval_batches(UARCH_A)
    eval_b = _eval_batches(UARCH_B)
    finals = {}
    for method in ("granite", "gradnorm", "tao_no_adapt", "tao"):
        with Timer() as t:
            joint = _joint(method, UARCH_A, UARCH_B, epochs=EPOCHS)
        te = 0.5 * (
            joint.eval_loss(eval_a, "A") + joint.eval_loss(eval_b, "B")
        )
        finals[method] = te
        emit(f"fig13/{method}", t.seconds * 1e6 / max(1, EPOCHS), f"test_loss={te:.4f}")
    order = sorted(finals, key=finals.get)
    emit("fig13/ordering", 0.0, "best_to_worst=" + ">".join(order))


def run_fig14() -> None:
    """Training-pair selection: Mahalanobis vs Euclidean vs random over a
    sampled design space (paper: MD best, ~6.3% vs 7.5% vs 8.5%)."""
    space = DesignSpace.sample(8, seed=42)
    mi, mj = space.select_pair(TRAIN_BENCHES[:2], method="mahalanobis",
                              instructions=3000)
    ei, ej = space.select_pair(TRAIN_BENCHES[:2], method="euclidean",
                              instructions=3000)
    ri, rj = space.select_pair(TRAIN_BENCHES[:2], method="random", seed=7)

    def embed_error(i, j) -> float:
        joint = _joint("tao", space[i], space[j],
                       epochs=max(3, EPOCHS // 2), seed=1)
        # transfer to unseen µArch C with frozen embeddings, measure CPI error
        ds_c = adjusted_dataset(UARCH_C, TRAIN_BENCHES[:1])
        model = joint.transfer(ds_c, epochs=max(2, EPOCHS // 3),
                               batch_size=16, lr=1e-3)
        errs = []
        for bench in TEST_BENCHES[:2]:
            ft, truth = ground_truth(UARCH_C, bench)
            sim = model.simulate(ft)
            errs.append(sim.error_vs(truth["cpi"]))
        return float(np.mean(errs))

    for name, (i, j) in (
        ("mahalanobis", (mi, mj)),
        ("euclidean", (ei, ej)),
        ("random", (ri, rj)),
    ):
        err = embed_error(i, j)
        emit(f"fig14/{name}", 0.0, f"pair=({i},{j});transfer_cpi_err={err:.2f}%")


def run_table5() -> None:
    """Transfer-learning training cost to a fixed loss target."""
    sess = session()
    ds_c = adjusted_dataset(UARCH_C, TRAIN_BENCHES[:2])
    small_c = ds_c.subsample(max(16, len(ds_c) // 5))

    # donor + shared embeddings from A/B joint training (reuse quick run)
    joint = _joint("tao", UARCH_A, UARCH_B, epochs=max(3, EPOCHS // 2), seed=2)

    # measure target loss = scratch's achievable loss, then time each regime
    with Timer() as t_scratch:
        scratch = sess.train(dataset=ds_c, epochs=EPOCHS, batch_size=16, lr=1e-3)
    target = scratch.losses[-1] * 1.1

    with Timer() as t_direct:
        direct = sess.train(
            dataset=ds_c, epochs=EPOCHS, batch_size=16, lr=1e-3,
            init=scratch, target_loss=target,
        )
    with Timer() as t_shared:
        shared = joint.transfer(
            small_c, epochs=EPOCHS, batch_size=16, lr=1e-3, target_loss=target,
        )
    emit(
        "table5/training_time",
        t_shared.seconds * 1e6,
        f"scratch_s={t_scratch.seconds:.1f};direct_ft_s={t_direct.seconds:.1f};"
        f"shared+ft_s={t_shared.seconds:.1f};"
        f"speedup={t_scratch.seconds/max(t_shared.seconds,1e-9):.1f}x(paper:29.5x);"
        f"losses={scratch.losses[-1]:.3f}/{direct.losses[-1]:.3f}/{shared.losses[-1]:.3f}",
    )


def run_table6() -> None:
    """One-time embedding-construction overhead decomposition."""
    from repro.core import measure_design_metrics, select_pair_mahalanobis

    with Timer() as t_sim:
        space = DesignSpace.sample(8, seed=11)
        metrics = measure_design_metrics(
            space.designs, TRAIN_BENCHES[:1], instructions=2000
        )
    with Timer() as t_sel:
        select_pair_mahalanobis(metrics)
    emit(
        "table6/overhead",
        t_sel.seconds * 1e6,
        f"design_sim_s={t_sim.seconds:.2f};selection_s={t_sel.seconds:.4f}"
        f"(paper:0.35h sim,0.1min select)",
    )


def run() -> None:
    run_fig13()
    run_fig14()
    run_table5()
    run_table6()
