"""(systems) ExecutionPlan sharding benchmark: the engine and the sweep
scheduler under an N-device ``data`` mesh vs the single-device plan.

Run under virtual CPU devices for the CI smoke
(``XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu
python -m benchmarks.run --only shard --json BENCH_shard.json``) — on a
2-core container the 8-way shard_map is pure scheduling overhead, so the
tracked claim is *equivalence + compile counts* (plus the overhead
trend); re-measure throughput on real TPU hardware.  Emits rows either
way: a single-device host just records the ``plan=single`` baseline.
"""
from __future__ import annotations

import numpy as np

from repro.distributed import data_mesh, topology_info
from repro.engine.metrics import DEFAULT_PHASE_CHUNKS

from .common import TEST_BENCHES, TEST_LEN, Timer, emit, session

# phase curves ride along to show windowed metrics stay device-resident
METRICS = ("cpi", "branch_mpki", "l1d_mpki", "cpi_phase")
REPS = 3


def _best_of(fn, reps=REPS) -> float:
    best = float("inf")
    for _ in range(reps):
        with Timer() as t:
            fn()
        best = min(best, t.seconds)
    return best


def run() -> None:
    topo = topology_info()
    n_dev = topo["device_count"]
    sess = session()
    bsz = sess.batch_size
    traces = {b: sess.capture(b, TEST_LEN) for b in TEST_BENCHES[:2]}
    models = {f"m{i}": sess.init_model(seed=i, name=f"m{i}") for i in range(2)}
    first = next(iter(models.values()))
    n_instr = sum(
        first.simulate(tr, metrics=METRICS).num_instructions
        for tr in traces.values()
    )  # also warms the single-device executable

    def sim_all(**kw):
        for tr in traces.values():
            first.simulate(tr, metrics=METRICS, **kw)

    single_secs = _best_of(sim_all)
    single_mips = n_instr / 1e6 / single_secs
    emit(
        "shard/engine_single",
        1e6 * single_secs,
        f"mips={single_mips:.4f};plan=single;devices={n_dev};batch={bsz}",
    )

    if n_dev < 2 or bsz % n_dev:
        emit(
            "shard/engine_sharded",
            0.0,
            f"skipped=single_device;devices={n_dev};plan=single",
        )
        return

    mesh = data_mesh()
    base = {tn: first.simulate(tr, metrics=METRICS) for tn, tr in traces.items()}
    shard_res = {
        tn: first.simulate(tr, metrics=METRICS, mesh=mesh)
        for tn, tr in traces.items()
    }  # warms the sharded executable
    # the sharded plan must reproduce the single-device metrics exactly
    # (CPU: bitwise in practice — the tier-1 suite pins this; here we
    # guard the bench itself against drift)
    for tn in traces:
        a, b = base[tn], shard_res[tn]
        assert a.branch_mpki == b.branch_mpki and a.l1d_mpki == b.l1d_mpki, tn
        assert np.allclose(a.cpi, b.cpi, rtol=1e-6), tn
        assert np.allclose(a.cpi_phase, b.cpi_phase, rtol=1e-5), tn
        assert b.cpi_phase.shape == (DEFAULT_PHASE_CHUNKS,)

    sharded_secs = _best_of(lambda: sim_all(mesh=mesh))
    sharded_mips = n_instr / 1e6 / sharded_secs
    emit(
        "shard/engine_sharded",
        1e6 * sharded_secs,
        f"mips={sharded_mips:.4f};plan=sharded;devices={n_dev};"
        f"mesh=data{n_dev};speedup={sharded_mips / single_mips:.2f}x;"
        f"metrics_equal=True;phase_chunks={DEFAULT_PHASE_CHUNKS}",
    )

    # data-sharded sweep: trace queue x data axis, one warm executable
    report = None
    for _ in range(REPS):
        r = sess.sweep(models, traces, metrics=METRICS, mesh=mesh)
        assert r.num_compiles == 0, r.num_compiles  # cache is warm
        if report is None or r.seconds < report.seconds:
            report = r
    emit(
        "shard/sweep_sharded",
        1e6 * report.seconds / report.num_traces,
        f"plan={report.plan_kind};shards={report.num_shards};"
        f"traces_per_s={report.traces_per_s:.2f};mips={report.mips:.4f};"
        f"compiles={report.num_compiles};"
        f"prepared_async={report.prepared_async}",
    )
